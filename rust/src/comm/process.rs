//! Distributed-memory communicator: ranks as forked processes connected by
//! a full mesh of Unix socket pairs.
//!
//! This is the configuration of the paper's Figure 4-5 ("MPJ Express
//! processes for parallel access to shared file ... of the Distributed
//! Memory Machine"): separate address spaces, kernel-mediated messaging.
//! The interconnect cost model ([`super::netmodel`]) layers the Barq /
//! RCMS fabric behaviour (GigE / Myrinet / InfiniBand) on top of the
//! loopback transport.
//!
//! ## Progress engine
//!
//! Sockets are non-blocking. `send` appends whole frames to a per-peer
//! outbound buffer and flushes what the socket accepts; whenever the
//! pipe is full it drains every readable peer into per-source pending
//! queues — so two ranks streaming large messages at each other cannot
//! deadlock (the classic eager/rendezvous problem; ROMIO's aggregation
//! exchange hits exactly this pattern). `recv` polls all peers, not just
//! the awaited source, for the same reason.
//!
//! Two threads of one rank — the application thread and the rank's
//! [`progress`](super::progress) thread — share the endpoint state.
//! Blocking waits therefore poll in bounded slices and release the state
//! lock between slices, so neither thread can starve the other: whoever
//! holds the lock drains *every* readable peer into the shared pending
//! queues (disjoint tag bands keep the two threads' traffic apart), and
//! the other thread gets a turn at most one slice later.

use std::collections::VecDeque;
use std::io;
use std::os::unix::io::RawFd;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::netmodel::{Link, TimeScale};
use super::progress::{self, ProgressLane};
use super::Comm;

/// Frame header: tag (i32 LE) + payload length (u64 LE).
const HDR: usize = 12;

struct PeerState {
    fd: RawFd,
    /// Accumulated unparsed inbound bytes.
    rbuf: Vec<u8>,
    /// Parsed frames not yet consumed by `recv`.
    pending: VecDeque<(i32, Vec<u8>)>,
    /// Outbound bytes the socket has not accepted yet. Senders append
    /// whole frames under the state lock (frame atomicity) and then
    /// flush in bounded slices, so the lock never blocks on a full pipe.
    wbuf: VecDeque<u8>,
    /// Total bytes ever appended to / flushed from `wbuf`: a sender's
    /// frame is on the wire once `wflushed` reaches the `wqueued` value
    /// observed at append time (another thread may flush it for us).
    wqueued: u64,
    /// See `wqueued`.
    wflushed: u64,
}

struct Inner {
    peers: Vec<Option<PeerState>>, // None at self index
}

impl Drop for Inner {
    fn drop(&mut self) {
        for p in self.peers.iter().flatten() {
            unsafe { libc::close(p.fd) };
        }
    }
}

/// Endpoint state shared between the application thread's handle and any
/// progress-lane endpoints cloned from it; the sockets close when the
/// last holder drops.
struct ProcShared {
    inner: Mutex<Inner>,
    /// The rank's lazily-spawned progress engines, one per lane.
    progress: progress::LaneBank,
}

/// Bounded poll slice for blocking waits: long enough that an idle
/// single-threaded rank burns ~no CPU, short enough that the rank's
/// other thread (application vs progress) never waits noticeably for
/// the state lock.
const POLL_SLICE_MS: i32 = 5;

/// Configuration for a process world.
#[derive(Clone, Copy, Debug)]
pub struct ProcConfig {
    /// Modelled interconnect.
    pub link: Link,
    /// Delay scale (set [`TimeScale::OFF`] for functional tests).
    pub scale: TimeScale,
}

impl Default for ProcConfig {
    fn default() -> Self {
        ProcConfig { link: Link::LOCAL, scale: TimeScale::OFF }
    }
}

/// A process-transport communicator handle (one per forked rank).
pub struct ProcComm {
    rank: usize,
    n: usize,
    shared: Arc<ProcShared>,
    cfg: ProcConfig,
}

// Safety: all fd state is behind the Mutex.
unsafe impl Sync for ProcComm {}

impl ProcComm {
    fn errno() -> i32 {
        io::Error::last_os_error().raw_os_error().unwrap_or(0)
    }

    /// Lock the shared endpoint state, recovering from poisoning: a
    /// fatal transport panic on one of the rank's threads (app or
    /// progress) must not turn every later operation on the other
    /// thread into a `PoisonError` abort. The state is byte
    /// buffers/queues whose partially-updated worst case is a protocol
    /// error on one peer, not memory unsafety.
    fn inner(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.shared.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Write as much of `buf` as the socket accepts right now
    /// (nonblocking); returns the bytes accepted.
    fn write_some(fd: RawFd, buf: &[u8], peer_rank: usize) -> usize {
        let mut written = 0;
        while written < buf.len() {
            let rc = unsafe {
                libc::write(
                    fd,
                    buf[written..].as_ptr() as *const libc::c_void,
                    buf.len() - written,
                )
            };
            if rc > 0 {
                written += rc as usize;
            } else {
                let e = Self::errno();
                if e == libc::EAGAIN || e == libc::EWOULDBLOCK {
                    break;
                }
                if e == libc::EINTR {
                    continue;
                }
                panic!("write to rank {peer_rank}: {}", io::Error::last_os_error());
            }
        }
        written
    }

    /// Take a pending frame matching `(src, tag)`, if one has been
    /// drained already (possibly by the rank's other thread).
    fn take_pending(inner: &mut Inner, src: usize, tag: i32) -> Option<Vec<u8>> {
        let p = inner.peers[src].as_mut().unwrap();
        let pos = p.pending.iter().position(|(t, _)| *t == tag)?;
        Some(p.pending.remove(pos).unwrap().1)
    }

    /// Drain every readable peer into its pending queue. `timeout_ms`
    /// bounds the wait for at least one readable fd (or `want_writable`
    /// becoming writable): `0` = just drain what is already there.
    fn progress(&self, inner: &mut Inner, timeout_ms: i32, want_writable: Option<RawFd>) {
        let mut fds: Vec<libc::pollfd> = Vec::with_capacity(self.n);
        let mut idx: Vec<usize> = Vec::with_capacity(self.n);
        for (i, p) in inner.peers.iter().enumerate() {
            if let Some(p) = p {
                let mut ev = libc::POLLIN;
                if Some(p.fd) == want_writable {
                    ev |= libc::POLLOUT;
                }
                fds.push(libc::pollfd { fd: p.fd, events: ev, revents: 0 });
                idx.push(i);
            }
        }
        let rc = unsafe { libc::poll(fds.as_mut_ptr(), fds.len() as libc::nfds_t, timeout_ms) };
        if rc < 0 {
            if Self::errno() == libc::EINTR {
                return;
            }
            panic!("poll failed: {}", io::Error::last_os_error());
        }
        for (f, &i) in fds.iter().zip(&idx) {
            if f.revents & (libc::POLLIN | libc::POLLHUP | libc::POLLERR) != 0 {
                self.drain_peer(inner.peers[i].as_mut().unwrap(), i);
            }
        }
    }

    /// Write as much of the peer's buffered outbound bytes as the socket
    /// accepts right now (nonblocking).
    fn flush_peer(p: &mut PeerState, peer_rank: usize) {
        while !p.wbuf.is_empty() {
            let n = {
                let (head, _) = p.wbuf.as_slices();
                Self::write_some(p.fd, head, peer_rank)
            };
            if n == 0 {
                break;
            }
            p.wbuf.drain(..n);
            p.wflushed += n as u64;
        }
    }

    /// Non-blockingly read whatever is available from one peer and parse
    /// complete frames into its pending queue.
    fn drain_peer(&self, p: &mut PeerState, peer_rank: usize) {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            let rc = unsafe {
                libc::read(p.fd, chunk.as_mut_ptr() as *mut libc::c_void, chunk.len())
            };
            if rc > 0 {
                p.rbuf.extend_from_slice(&chunk[..rc as usize]);
                if (rc as usize) < chunk.len() {
                    break;
                }
            } else if rc == 0 {
                // Peer closed. Parse what we have; a later recv on this
                // peer with nothing pending is a hard error.
                break;
            } else {
                let e = Self::errno();
                if e == libc::EAGAIN || e == libc::EWOULDBLOCK {
                    break;
                }
                if e == libc::EINTR {
                    continue;
                }
                panic!("read from rank {peer_rank}: {}", io::Error::last_os_error());
            }
        }
        // Parse complete frames.
        let mut pos = 0;
        while p.rbuf.len() - pos >= HDR {
            let tag = i32::from_le_bytes(p.rbuf[pos..pos + 4].try_into().unwrap());
            let len = u64::from_le_bytes(p.rbuf[pos + 4..pos + 12].try_into().unwrap()) as usize;
            if p.rbuf.len() - pos - HDR < len {
                break;
            }
            let payload = p.rbuf[pos + HDR..pos + HDR + len].to_vec();
            p.pending.push_back((tag, payload));
            pos += HDR + len;
        }
        if pos > 0 {
            p.rbuf.drain(..pos);
        }
    }
}

impl Comm for ProcComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.n
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        assert!(dest < self.n && dest != self.rank, "send to rank {dest}");
        // Pay the modelled wire cost up front (sender-side occupancy).
        self.cfg.scale.pay(self.cfg.link.transfer_time(data.len()));

        let mut frame = Vec::with_capacity(HDR + data.len());
        frame.extend_from_slice(&tag.to_le_bytes());
        frame.extend_from_slice(&(data.len() as u64).to_le_bytes());
        frame.extend_from_slice(data);

        // Append the whole frame to the peer's outbound buffer under the
        // lock (frames from the rank's two threads must land atomically
        // on the socket), then flush in bounded slices with the lock
        // released between slices — the invariant that keeps either
        // thread from starving the other on a full pipe. Whichever
        // thread holds the lock flushes the shared buffer, so our frame
        // may well reach the wire while the other thread holds it.
        let (fd, target) = {
            let mut inner = self.inner();
            let p = inner.peers[dest].as_mut().unwrap();
            p.wqueued += frame.len() as u64;
            let target = p.wqueued;
            let fd = p.fd;
            if p.wbuf.is_empty() {
                // Fast path: the socket usually accepts the whole frame
                // at once — write straight from it and buffer only the
                // unaccepted tail, avoiding the staging copy.
                let n = Self::write_some(fd, &frame, dest);
                p.wflushed += n as u64;
                if n == frame.len() {
                    return;
                }
                p.wbuf.extend(frame[n..].iter().copied());
            } else {
                p.wbuf.extend(frame);
                Self::flush_peer(&mut *p, dest);
                if p.wflushed >= target {
                    return;
                }
            }
            (fd, target)
        };
        loop {
            {
                let mut inner = self.inner();
                // Wait (bounded) for writability, draining inbound so
                // the peer — possibly blocked writing to us — can make
                // progress too, then push more bytes out.
                self.progress(&mut inner, POLL_SLICE_MS, Some(fd));
                let p = inner.peers[dest].as_mut().unwrap();
                Self::flush_peer(&mut *p, dest);
                if p.wflushed >= target {
                    return;
                }
            }
            std::thread::yield_now();
        }
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        assert!(src < self.n && src != self.rank, "recv from rank {src}");
        loop {
            {
                let mut inner = self.inner();
                // The awaited frame may already be pending — drained by
                // this thread earlier or by the rank's other thread.
                if let Some(msg) = Self::take_pending(&mut inner, src, tag) {
                    return msg;
                }
                self.progress(&mut inner, POLL_SLICE_MS, None);
                if let Some(msg) = Self::take_pending(&mut inner, src, tag) {
                    return msg;
                }
            }
            // Lock released between slices: the rank's other thread
            // (application vs progress) takes its turn.
            std::thread::yield_now();
        }
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        assert!(src < self.n && src != self.rank, "try_recv from rank {src}");
        let mut inner = self.inner();
        self.progress(&mut inner, 0, None);
        Self::take_pending(&mut inner, src, tag)
    }

    fn progress_lane_at(&self, lane: usize) -> Option<ProgressLane> {
        let endpoint: Arc<dyn Comm> = Arc::new(ProcComm {
            rank: self.rank,
            n: self.n,
            shared: self.shared.clone(),
            cfg: self.cfg,
        });
        Some(progress::lane(&self.shared.progress, self.rank, lane, endpoint))
    }
}

/// Outcome of a process-world run, returned at rank 0.
pub struct WorldResult<R> {
    /// Rank 0's return value.
    pub value: R,
}

/// Fork `n - 1` child ranks (the caller becomes rank 0), run `f` in every
/// rank, wait for the children, and return rank 0's result. Children exit
/// after `f`; a non-zero child exit panics the parent.
///
/// Must be called when it is safe to fork (the bench/example binaries call
/// it from `main` before spawning threads; PJRT clients must be created
/// *after* the fork, in each rank).
pub fn run<R, F>(n: usize, cfg: ProcConfig, f: F) -> R
where
    F: Fn(&ProcComm) -> R,
{
    assert!(n > 0);
    if n == 1 {
        let comm = ProcComm {
            rank: 0,
            n: 1,
            shared: Arc::new(ProcShared {
                inner: Mutex::new(Inner { peers: vec![None] }),
                progress: progress::LaneBank::new(),
            }),
            cfg,
        };
        return f(&comm);
    }
    // Socket pairs for every unordered pair {i, j}, i < j.
    let mut pair_fds = vec![vec![(-1, -1); n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let mut sv = [0; 2];
            let rc = unsafe { libc::socketpair(libc::AF_UNIX, libc::SOCK_STREAM, 0, sv.as_mut_ptr()) };
            assert_eq!(rc, 0, "socketpair: {}", io::Error::last_os_error());
            pair_fds[i][j] = (sv[0], sv[1]); // sv[0] for rank i, sv[1] for rank j
        }
    }
    let close_all_except = |me: usize| {
        for i in 0..n {
            for j in (i + 1)..n {
                let (a, b) = pair_fds[i][j];
                if i != me {
                    unsafe { libc::close(a) };
                }
                if j != me {
                    unsafe { libc::close(b) };
                }
            }
        }
    };
    let build_comm = |me: usize| -> ProcComm {
        let mut peers: Vec<Option<PeerState>> = (0..n).map(|_| None).collect();
        for other in 0..n {
            if other == me {
                continue;
            }
            let fd = if me < other { pair_fds[me][other].0 } else { pair_fds[other][me].1 };
            // Non-blocking mode for the progress engine.
            unsafe {
                let fl = libc::fcntl(fd, libc::F_GETFL);
                libc::fcntl(fd, libc::F_SETFL, fl | libc::O_NONBLOCK);
            }
            peers[other] = Some(PeerState {
                fd,
                rbuf: Vec::new(),
                pending: VecDeque::new(),
                wbuf: VecDeque::new(),
                wqueued: 0,
                wflushed: 0,
            });
        }
        ProcComm {
            rank: me,
            n,
            shared: Arc::new(ProcShared {
                inner: Mutex::new(Inner { peers }),
                progress: progress::LaneBank::new(),
            }),
            cfg,
        }
    };

    let mut children = Vec::with_capacity(n - 1);
    for rank in 1..n {
        let pid = unsafe { libc::fork() };
        assert!(pid >= 0, "fork: {}", io::Error::last_os_error());
        if pid == 0 {
            // Child: become `rank`, run, exit without unwinding into the
            // parent's state.
            close_all_except(rank);
            let comm = build_comm(rank);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&comm);
            }));
            let code = if result.is_ok() { 0 } else { 17 };
            unsafe { libc::_exit(code) };
        }
        children.push(pid);
    }
    // Parent: rank 0.
    close_all_except(0);
    let comm = build_comm(0);
    let value = f(&comm);
    drop(comm);
    // Reap.
    for pid in children {
        let mut status = 0;
        let rc = unsafe { libc::waitpid(pid, &mut status, 0) };
        assert!(rc == pid, "waitpid: {}", io::Error::last_os_error());
        let exited_ok = libc::WIFEXITED(status) && libc::WEXITSTATUS(status) == 0;
        assert!(exited_ok, "child rank (pid {pid}) failed with status {status:#x}");
    }
    value
}

/// Convenience wrapper: functional defaults (no modelled delays).
pub fn run_local<R, F>(n: usize, f: F) -> R
where
    F: Fn(&ProcComm) -> R,
{
    run(n, ProcConfig::default(), f)
}

/// Rough helper used by benches: the wall-clock of one modelled GigE
/// round-trip, for sanity checks.
pub fn modelled_rtt(cfg: &ProcConfig, bytes: usize) -> Duration {
    cfg.scale.scale(cfg.link.transfer_time(bytes)) * 2
}

// Socket teardown lives in `Inner::drop`: the fds close when the last
// holder of the shared endpoint state (application handle or an
// in-flight progress-lane endpoint) goes away.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ReduceOp;

    // NOTE: these tests fork. The cargo test harness is multi-threaded,
    // which is safe here because children only touch their own fds and
    // glibc's atfork handlers keep malloc usable, but we keep the worlds
    // small and the work minimal.

    #[test]
    fn fork_world_ranks_and_barrier() {
        let v = run_local(4, |c| {
            c.barrier();
            c.allreduce_i64(ReduceOp::Sum, c.rank() as i64)
        });
        assert_eq!(v, 0 + 1 + 2 + 3);
    }

    #[test]
    fn send_recv_across_processes() {
        let got = run_local(2, |c| {
            if c.rank() == 0 {
                c.send(1, 5, b"hello child");
                c.recv(1, 6)
            } else {
                let m = c.recv(0, 5);
                c.send(0, 6, &m);
                Vec::new()
            }
        });
        assert_eq!(got, b"hello child");
    }

    #[test]
    fn large_bidirectional_streams_do_not_deadlock() {
        // Both ranks send 4 MiB to each other simultaneously — only the
        // progress engine prevents a pipe-full deadlock here.
        let ok = run_local(2, |c| {
            let big = vec![c.rank() as u8; 4 << 20];
            let other = 1 - c.rank();
            c.send(other, 9, &big);
            let got = c.recv(other, 9);
            got.len() == 4 << 20 && got.iter().all(|&b| b == other as u8)
        });
        assert!(ok);
    }

    #[test]
    fn collectives_across_processes() {
        let parts = run_local(3, |c| {
            let g = c.allgather(&[c.rank() as u8 + 10]);
            let mut b = vec![0u8; 3];
            if c.rank() == 1 {
                b = vec![7, 8, 9];
            }
            c.bcast(1, &mut b);
            assert_eq!(b, vec![7, 8, 9]);
            g
        });
        assert_eq!(parts, vec![vec![10u8], vec![11u8], vec![12u8]]);
    }

    #[test]
    fn alltoall_across_processes() {
        let out = run_local(3, |c| {
            let parts: Vec<Vec<u8>> = (0..3).map(|d| vec![(c.rank() * 3 + d) as u8]).collect();
            c.alltoall(&parts)
        });
        // Rank 0 receives element [src*3 + 0] from each src.
        assert_eq!(out, vec![vec![0u8], vec![3u8], vec![6u8]]);
    }

    #[test]
    fn modelled_link_delays_are_paid() {
        use std::time::Instant;
        let cfg = ProcConfig { link: Link::GIGE, scale: TimeScale(1.0) };
        let elapsed = run(2, cfg, |c| {
            let start = Instant::now();
            if c.rank() == 0 {
                // 1 MiB at 110 MB/s ≈ 9.5 ms modelled.
                c.send(1, 1, &vec![0u8; 1 << 20]);
            } else {
                let _ = c.recv(0, 1);
            }
            start.elapsed()
        });
        assert!(elapsed >= Duration::from_millis(8), "GigE model not paid: {elapsed:?}");
    }
}
