//! Interconnect performance model.
//!
//! The paper's two testbeds use Gigabit Ethernet + Myrinet (Barq, Table
//! 4-1) and InfiniBand + GigE (RCMS, Table 4-2). We run every rank on one
//! host, so the *transport* is a Unix socket either way; this module
//! supplies the latency/bandwidth cost model that makes a simulated link
//! behave like the paper's interconnects. Storage backends reuse the same
//! model for NFS RPC costs.
//!
//! Costs are injected as real (scaled) delays so measured bandwidth keeps
//! the paper's *shape*; `TimeScale` shrinks all delays uniformly so the
//! bench suite stays fast (relative numbers are unchanged).

use std::time::Duration;

/// A link class with one-way latency and sustained bandwidth.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    /// One-way small-message latency.
    pub latency_us: f64,
    /// Sustained bandwidth in MB/s.
    pub bandwidth_mbs: f64,
    /// Human-readable name.
    pub name: &'static str,
}

impl Link {
    /// Gigabit Ethernet (Barq cluster default fabric).
    pub const GIGE: Link = Link { latency_us: 55.0, bandwidth_mbs: 110.0, name: "GigE" };
    /// Myrinet (Barq cluster HPC fabric).
    pub const MYRINET: Link = Link { latency_us: 7.0, bandwidth_mbs: 240.0, name: "Myrinet" };
    /// 40 Gb/s InfiniBand (RCMS cluster fabric).
    pub const INFINIBAND: Link =
        Link { latency_us: 2.0, bandwidth_mbs: 3200.0, name: "InfiniBand" };
    /// Loopback / shared memory (no injected cost).
    pub const LOCAL: Link = Link { latency_us: 0.0, bandwidth_mbs: f64::INFINITY, name: "local" };

    /// Modelled one-way transfer time for `bytes` at scale 1.0.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        if self.bandwidth_mbs.is_infinite() && self.latency_us == 0.0 {
            return Duration::ZERO;
        }
        let bw = self.bandwidth_mbs * 1e6; // bytes/sec
        let secs = self.latency_us * 1e-6
            + if bw.is_finite() { bytes as f64 / bw } else { 0.0 };
        Duration::from_secs_f64(secs)
    }
}

/// Uniform scale factor applied to all modelled delays. `0.0` disables
/// delay injection entirely (functional tests); `1.0` is real time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimeScale(pub f64);

impl Default for TimeScale {
    fn default() -> Self {
        TimeScale(1.0)
    }
}

impl TimeScale {
    /// No injected delays (functional testing).
    pub const OFF: TimeScale = TimeScale(0.0);

    /// Apply the scale to a modelled duration.
    pub fn scale(&self, d: Duration) -> Duration {
        if self.0 == 0.0 {
            Duration::ZERO
        } else {
            d.mul_f64(self.0)
        }
    }

    /// Sleep for the scaled duration (no-op when zero or sub-microsecond).
    pub fn pay(&self, d: Duration) {
        let s = self.scale(d);
        if s > Duration::from_nanos(500) {
            spin_sleep(s);
        }
    }
}

/// Hybrid sleep: OS sleep for the bulk, spin for the tail, so short
/// modelled delays (microseconds) stay accurate enough for bandwidth
/// shapes without burning a core on long ones.
fn spin_sleep(d: Duration) {
    let start = std::time::Instant::now();
    if d > Duration::from_micros(200) {
        std::thread::sleep(d - Duration::from_micros(100));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let l = Link::GIGE;
        let t1 = l.transfer_time(1 << 20);
        let t2 = l.transfer_time(2 << 20);
        assert!(t2 > t1);
        // 1 MiB at 110 MB/s ≈ 9.5 ms (+55 µs latency).
        assert!((t1.as_secs_f64() - (1048576.0 / 110e6 + 55e-6)).abs() < 1e-6);
    }

    #[test]
    fn local_link_is_free() {
        assert_eq!(Link::LOCAL.transfer_time(usize::MAX >> 8), Duration::ZERO);
    }

    #[test]
    fn timescale_off_pays_nothing() {
        let start = std::time::Instant::now();
        TimeScale::OFF.pay(Duration::from_secs(5));
        assert!(start.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn timescale_scales() {
        let ts = TimeScale(0.5);
        assert_eq!(ts.scale(Duration::from_millis(10)), Duration::from_millis(5));
    }

    #[test]
    fn pay_sleeps_approximately() {
        let ts = TimeScale(1.0);
        let start = std::time::Instant::now();
        ts.pay(Duration::from_millis(2));
        let el = start.elapsed();
        assert!(el >= Duration::from_millis(2), "slept only {el:?}");
        assert!(el < Duration::from_millis(40), "overslept {el:?}");
    }

    #[test]
    fn ordering_of_fabrics() {
        // Latency: IB < Myrinet < GigE; bandwidth the reverse order.
        assert!(Link::INFINIBAND.latency_us < Link::MYRINET.latency_us);
        assert!(Link::MYRINET.latency_us < Link::GIGE.latency_us);
        assert!(Link::INFINIBAND.bandwidth_mbs > Link::MYRINET.bandwidth_mbs);
        assert!(Link::MYRINET.bandwidth_mbs > Link::GIGE.bandwidth_mbs);
    }
}
