//! Derived datatypes (MPI-2 chapter 4) — the substrate MPJ Express lacked.
//!
//! The paper's §5 names "required data types with holes for views" as the
//! missing piece that kept file views out of the MPJ-IO prototype. This
//! module builds that substrate: primitive types, the seven derived-type
//! constructors (contiguous, vector, hvector, indexed, hindexed, struct,
//! subarray) plus the distributed-array (`darray`) constructor the MPI-2.2
//! change list calls out as "important for MPI-IO", with the type-map
//! flattening that the file-view access engine consumes.
//!
//! A datatype is a *type map*: a sorted list of `(byte offset, primitive,
//! count)` segments relative to the instance origin, plus `lb`/`extent`
//! bookkeeping so consecutive instances tile with holes. Flattening a
//! `(count, datatype)` pair yields the byte runs that the I/O engine
//! zips against the file-side view runs (the classic ROMIO two-cursor
//! copy).

use std::fmt;
use std::sync::Arc;

/// File offsets (`mpj.Offset`): 64-bit, per §7.2.6.7 ("MPI_Offset type is
/// used instead of int ... to represent the size of the largest file").
pub type Offset = i64;

/// Primitive element types supported by the library (the paper's
/// byte-oriented I/O model: §1.4).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Prim {
    /// 8-bit byte (`MPI_BYTE`).
    Byte,
    /// 16-bit signed integer (`MPI_SHORT`).
    Short,
    /// 32-bit signed integer (`MPI_INT`).
    Int,
    /// 64-bit signed integer (`MPI_LONG` in the Java binding).
    Long,
    /// 32-bit IEEE float (`MPI_FLOAT`).
    Float,
    /// 64-bit IEEE double (`MPI_DOUBLE`).
    Double,
    /// 16-bit unsigned char (`MPI_CHAR` in the Java binding).
    Char,
    /// Boolean, one byte (`MPI_BOOLEAN`).
    Boolean,
}

impl Prim {
    /// Size of the primitive in bytes (native representation).
    pub const fn size(self) -> usize {
        match self {
            Prim::Byte | Prim::Boolean => 1,
            Prim::Short | Prim::Char => 2,
            Prim::Int | Prim::Float => 4,
            Prim::Long | Prim::Double => 8,
        }
    }

    /// Size in the `external32` data representation (§7.2.5.2). For the
    /// types we support external32 sizes equal native sizes; the
    /// difference is byte order, handled by [`crate::io::datarep`].
    pub const fn external32_size(self) -> usize {
        self.size()
    }

    /// Human-readable name matching the MPJ constants.
    pub const fn name(self) -> &'static str {
        match self {
            Prim::Byte => "BYTE",
            Prim::Short => "SHORT",
            Prim::Int => "INT",
            Prim::Long => "LONG",
            Prim::Float => "FLOAT",
            Prim::Double => "DOUBLE",
            Prim::Char => "CHAR",
            Prim::Boolean => "BOOLEAN",
        }
    }
}

/// One entry of a flattened type map: `count` consecutive elements of
/// `prim` starting `offset` bytes from the instance origin.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Segment {
    /// Byte offset from the origin of the instance.
    pub offset: i64,
    /// Primitive element type of this run.
    pub prim: Prim,
    /// Number of consecutive elements.
    pub count: usize,
}

impl Segment {
    /// Length of the run in bytes.
    pub fn len(&self) -> usize {
        self.prim.size() * self.count
    }

    /// True if the run holds no bytes.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exclusive end offset of the run.
    pub fn end(&self) -> i64 {
        self.offset + self.len() as i64
    }
}

/// Row-major vs column-major array storage order (subarray/darray).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ArrayOrder {
    /// C order (row-major) — `ORDER_C`.
    C,
    /// Fortran order (column-major) — `ORDER_FORTRAN`.
    Fortran,
}

/// Interior of a derived datatype (opaque; constructed via the
/// [`Datatype`] constructors).
#[derive(Debug)]
pub struct Derived {
    /// Sorted, coalesced type map for one instance.
    map: Vec<Segment>,
    /// Total payload bytes (sum of segment lengths; holes excluded).
    size: usize,
    /// Lower bound (bytes).
    lb: i64,
    /// Upper bound (bytes); `extent = ub - lb`.
    ub: i64,
    /// Debug name, e.g. `vector(3,2,4,INT)`.
    name: String,
}

/// A (possibly derived) datatype. Cheap to clone; derived interiors are
/// reference counted.
#[derive(Clone, Debug)]
pub enum Datatype {
    /// A primitive type.
    Prim(Prim),
    /// A derived type with an explicit type map.
    Derived(Arc<Derived>),
}

impl PartialEq for Datatype {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Datatype::Prim(a), Datatype::Prim(b)) => a == b,
            (Datatype::Derived(a), Datatype::Derived(b)) => {
                Arc::ptr_eq(a, b) || (a.map == b.map && a.lb == b.lb && a.ub == b.ub)
            }
            _ => false,
        }
    }
}

impl fmt::Display for Datatype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Datatype::Prim(p) => write!(f, "{}", p.name()),
            Datatype::Derived(d) => write!(f, "{}", d.name),
        }
    }
}

/// Error from a datatype constructor.
#[derive(Debug, PartialEq, Eq)]
pub enum TypeError {
    /// Mismatched argument vector lengths for indexed/struct constructors.
    ArgMismatch(String),
    /// Subarray bounds fall outside the full array.
    SubarrayBounds(String),
    /// A size/stride argument was invalid (zero or negative where not allowed).
    InvalidArg(String),
}

impl fmt::Display for TypeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TypeError::ArgMismatch(m) => write!(f, "argument length mismatch: {m}"),
            TypeError::SubarrayBounds(m) => write!(f, "subarray out of bounds: {m}"),
            TypeError::InvalidArg(m) => write!(f, "invalid argument: {m}"),
        }
    }
}

impl std::error::Error for TypeError {}

impl Datatype {
    /// `MPI_BYTE`.
    pub const BYTE: Datatype = Datatype::Prim(Prim::Byte);
    /// `MPI_SHORT`.
    pub const SHORT: Datatype = Datatype::Prim(Prim::Short);
    /// `MPI_INT`.
    pub const INT: Datatype = Datatype::Prim(Prim::Int);
    /// `MPI_LONG`.
    pub const LONG: Datatype = Datatype::Prim(Prim::Long);
    /// `MPI_FLOAT`.
    pub const FLOAT: Datatype = Datatype::Prim(Prim::Float);
    /// `MPI_DOUBLE`.
    pub const DOUBLE: Datatype = Datatype::Prim(Prim::Double);
    /// `MPI_CHAR`.
    pub const CHAR: Datatype = Datatype::Prim(Prim::Char);
    /// `MPI_BOOLEAN`.
    pub const BOOLEAN: Datatype = Datatype::Prim(Prim::Boolean);

    // ------------------------------------------------------------------
    // Queries
    // ------------------------------------------------------------------

    /// Payload size in bytes (holes excluded) — `MPI_Type_size`.
    pub fn size(&self) -> usize {
        match self {
            Datatype::Prim(p) => p.size(),
            Datatype::Derived(d) => d.size,
        }
    }

    /// Extent in bytes (`ub - lb`) — `MPI_Type_get_extent`.
    pub fn extent(&self) -> i64 {
        match self {
            Datatype::Prim(p) => p.size() as i64,
            Datatype::Derived(d) => d.ub - d.lb,
        }
    }

    /// Lower bound in bytes.
    pub fn lb(&self) -> i64 {
        match self {
            Datatype::Prim(_) => 0,
            Datatype::Derived(d) => d.lb,
        }
    }

    /// Upper bound in bytes.
    pub fn ub(&self) -> i64 {
        match self {
            Datatype::Prim(p) => p.size() as i64,
            Datatype::Derived(d) => d.ub,
        }
    }

    /// True lower bound: offset of the first real byte (`MPI_Type_get_true_extent`).
    pub fn true_lb(&self) -> i64 {
        match self {
            Datatype::Prim(_) => 0,
            Datatype::Derived(d) => d.map.first().map_or(0, |s| s.offset),
        }
    }

    /// True extent: span of real bytes, holes at the edges excluded.
    pub fn true_extent(&self) -> i64 {
        match self {
            Datatype::Prim(p) => p.size() as i64,
            Datatype::Derived(d) => {
                let lo = d.map.first().map_or(0, |s| s.offset);
                let hi = d.map.last().map_or(0, |s| s.end());
                hi - lo
            }
        }
    }

    /// The flattened type map for one instance.
    pub fn segments(&self) -> Vec<Segment> {
        match self {
            Datatype::Prim(p) => vec![Segment { offset: 0, prim: *p, count: 1 }],
            Datatype::Derived(d) => d.map.clone(),
        }
    }

    /// Number of segments in one instance (1 for primitives).
    pub fn segment_count(&self) -> usize {
        match self {
            Datatype::Prim(_) => 1,
            Datatype::Derived(d) => d.map.len(),
        }
    }

    /// True iff the type is a single gap-free run whose extent equals its
    /// size (so `count` instances tile contiguously).
    pub fn is_contiguous(&self) -> bool {
        match self {
            Datatype::Prim(_) => true,
            Datatype::Derived(d) => {
                d.map.len() == 1
                    && d.map[0].offset == d.lb
                    && d.map[0].len() as i64 == d.ub - d.lb
            }
        }
    }

    /// The primitive of the first segment (used by datarep conversion and
    /// view etype checks).
    pub fn base_prim(&self) -> Prim {
        match self {
            Datatype::Prim(p) => *p,
            Datatype::Derived(d) => d.map.first().map_or(Prim::Byte, |s| s.prim),
        }
    }

    /// True if every segment holds the same primitive.
    pub fn is_homogeneous(&self) -> bool {
        match self {
            Datatype::Prim(_) => true,
            Datatype::Derived(d) => {
                d.map.windows(2).all(|w| w[0].prim == w[1].prim)
            }
        }
    }

    /// Commit the datatype (`MPI_Type_commit`). Types in this library are
    /// usable immediately; commit is a no-op kept for API fidelity.
    pub fn commit(&self) -> &Self {
        self
    }

    /// Iterate byte runs `(offset, len)` for `count` consecutive instances
    /// tiled by `extent`, starting at relative offset 0. Adjacent runs of
    /// different primitives are *not* merged (datarep conversion needs the
    /// primitive boundaries); use [`ByteRuns::coalesced`] when only byte
    /// geometry matters.
    pub fn byte_runs(&self, count: usize) -> ByteRuns {
        ByteRuns::new(self.clone(), count)
    }

    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    /// `count` consecutive copies — `MPI_Type_contiguous`.
    pub fn contiguous(count: usize, base: &Datatype) -> Result<Datatype, TypeError> {
        Self::vector(count, 1, 1, base)
    }

    /// `count` blocks of `blocklen` copies, block starts `stride`
    /// *elements* apart — `MPI_Type_vector`.
    pub fn vector(
        count: usize,
        blocklen: usize,
        stride: i64,
        base: &Datatype,
    ) -> Result<Datatype, TypeError> {
        Self::hvector(count, blocklen, stride * base.extent(), base)
    }

    /// Like [`Datatype::vector`] but `stride` is in *bytes* —
    /// `MPI_Type_create_hvector`.
    pub fn hvector(
        count: usize,
        blocklen: usize,
        stride_bytes: i64,
        base: &Datatype,
    ) -> Result<Datatype, TypeError> {
        let mut map = Vec::new();
        let bext = base.extent();
        for i in 0..count {
            let block_origin = i as i64 * stride_bytes;
            for j in 0..blocklen {
                append_instance(&mut map, base, block_origin + j as i64 * bext);
            }
        }
        // The MPI ub of a vector covers the last block's last element.
        let natural_ub = if count == 0 || blocklen == 0 {
            0
        } else {
            (count - 1) as i64 * stride_bytes + blocklen as i64 * bext
        };
        Ok(finish(map, 0, natural_ub, format!("hvector({count},{blocklen},{stride_bytes},{base})")))
    }

    /// Blocks of varying lengths at element displacements —
    /// `MPI_Type_indexed`.
    pub fn indexed(
        blocklens: &[usize],
        displacements: &[i64],
        base: &Datatype,
    ) -> Result<Datatype, TypeError> {
        if blocklens.len() != displacements.len() {
            return Err(TypeError::ArgMismatch(format!(
                "indexed: {} blocklens vs {} displacements",
                blocklens.len(),
                displacements.len()
            )));
        }
        let bext = base.extent();
        let disp_bytes: Vec<i64> = displacements.iter().map(|d| d * bext).collect();
        Self::hindexed(blocklens, &disp_bytes, base)
    }

    /// Like [`Datatype::indexed`] with byte displacements —
    /// `MPI_Type_create_hindexed`.
    pub fn hindexed(
        blocklens: &[usize],
        disp_bytes: &[i64],
        base: &Datatype,
    ) -> Result<Datatype, TypeError> {
        if blocklens.len() != disp_bytes.len() {
            return Err(TypeError::ArgMismatch(format!(
                "hindexed: {} blocklens vs {} displacements",
                blocklens.len(),
                disp_bytes.len()
            )));
        }
        let bext = base.extent();
        let mut map = Vec::new();
        let mut ub = 0i64;
        let mut lb = i64::MAX;
        for (&bl, &disp) in blocklens.iter().zip(disp_bytes) {
            for j in 0..bl {
                append_instance(&mut map, base, disp + j as i64 * bext);
            }
            lb = lb.min(disp);
            ub = ub.max(disp + bl as i64 * bext);
        }
        if lb == i64::MAX {
            lb = 0;
        }
        Ok(finish(map, lb.min(0).max(lb), ub, format!("hindexed({} blocks,{base})", blocklens.len())))
    }

    /// Heterogeneous struct type — `MPI_Type_create_struct`.
    pub fn struct_(
        blocklens: &[usize],
        disp_bytes: &[i64],
        types: &[Datatype],
    ) -> Result<Datatype, TypeError> {
        if blocklens.len() != disp_bytes.len() || blocklens.len() != types.len() {
            return Err(TypeError::ArgMismatch(format!(
                "struct: {} blocklens / {} displacements / {} types",
                blocklens.len(),
                disp_bytes.len(),
                types.len()
            )));
        }
        let mut map = Vec::new();
        let mut ub = 0i64;
        let mut lb = 0i64;
        for ((&bl, &disp), ty) in blocklens.iter().zip(disp_bytes).zip(types) {
            let bext = ty.extent();
            for j in 0..bl {
                append_instance(&mut map, ty, disp + j as i64 * bext);
            }
            lb = lb.min(disp);
            ub = ub.max(disp + bl as i64 * bext);
        }
        Ok(finish(map, lb, ub, format!("struct({} members)", types.len())))
    }

    /// Subarray filetype constructor (§7.2.9.2): selects the block
    /// `starts[d] .. starts[d]+subsizes[d]` of an n-dimensional array of
    /// `sizes[d]` elements. The extent covers the *full* array, which is
    /// what makes it a filetype "with holes".
    pub fn subarray(
        sizes: &[usize],
        subsizes: &[usize],
        starts: &[usize],
        order: ArrayOrder,
        base: &Datatype,
    ) -> Result<Datatype, TypeError> {
        let ndims = sizes.len();
        if subsizes.len() != ndims || starts.len() != ndims {
            return Err(TypeError::ArgMismatch(format!(
                "subarray: sizes={ndims}, subsizes={}, starts={}",
                subsizes.len(),
                starts.len()
            )));
        }
        if ndims == 0 {
            return Err(TypeError::InvalidArg("subarray: zero dimensions".into()));
        }
        for d in 0..ndims {
            if subsizes[d] == 0 || sizes[d] == 0 {
                return Err(TypeError::InvalidArg(format!(
                    "subarray: zero size in dim {d}"
                )));
            }
            if starts[d] + subsizes[d] > sizes[d] {
                return Err(TypeError::SubarrayBounds(format!(
                    "dim {d}: start {} + subsize {} > size {}",
                    starts[d], subsizes[d], sizes[d]
                )));
            }
        }
        // Normalize to row-major: for Fortran order reverse the dims.
        let (sizes_c, subsizes_c, starts_c): (Vec<_>, Vec<_>, Vec<_>) = match order {
            ArrayOrder::C => (sizes.to_vec(), subsizes.to_vec(), starts.to_vec()),
            ArrayOrder::Fortran => (
                sizes.iter().rev().copied().collect(),
                subsizes.iter().rev().copied().collect(),
                starts.iter().rev().copied().collect(),
            ),
        };
        let bext = base.extent();
        // Row-major strides of the full array, in elements of `base`.
        let mut strides = vec![1i64; ndims];
        for d in (0..ndims.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * sizes_c[d + 1] as i64;
        }
        let total_elems: i64 = sizes_c.iter().map(|&s| s as i64).product();
        // Enumerate rows of the innermost dimension: each yields one
        // contiguous run of subsizes_c[ndims-1] base instances.
        let mut map = Vec::new();
        let inner = subsizes_c[ndims - 1];
        let outer_dims = &subsizes_c[..ndims - 1];
        let mut idx = vec![0usize; outer_dims.len()];
        loop {
            let mut elem_off = starts_c[ndims - 1] as i64 * strides[ndims - 1];
            for (d, &i) in idx.iter().enumerate() {
                elem_off += (starts_c[d] + i) as i64 * strides[d];
            }
            for j in 0..inner {
                append_instance(&mut map, base, (elem_off + j as i64) * bext);
            }
            // Odometer increment over the outer dims.
            let mut d = outer_dims.len();
            loop {
                if d == 0 {
                    break;
                }
                d -= 1;
                idx[d] += 1;
                if idx[d] < outer_dims[d] {
                    break;
                }
                idx[d] = 0;
                if d == 0 {
                    d = usize::MAX; // done flag
                    break;
                }
            }
            if outer_dims.is_empty() || d == usize::MAX {
                break;
            }
        }
        Ok(finish(
            map,
            0,
            total_elems * bext,
            format!("subarray({sizes:?}/{subsizes:?}@{starts:?},{base})"),
        ))
    }

    /// Block-distributed array constructor (`MPI_Type_create_darray` with
    /// `MPI_DISTRIBUTE_BLOCK` in every dimension) — the MPI-2.2 change the
    /// paper's §7.2.1.1 flags as "important for MPI-IO". Returns the
    /// filetype describing `rank`'s block of an n-D array distributed over
    /// a process grid `psizes`.
    pub fn darray_block(
        size_global: &[usize],
        psizes: &[usize],
        rank: usize,
        order: ArrayOrder,
        base: &Datatype,
    ) -> Result<Datatype, TypeError> {
        let (starts, subsizes) = Self::block_decompose(size_global, psizes, rank)?;
        Self::subarray(size_global, &subsizes, &starts, order, base)
    }

    /// The `(starts, subsizes)` of `rank`'s block of an n-D array
    /// distributed over a process grid `psizes` — the decomposition
    /// arithmetic behind [`Datatype::darray_block`], exposed for callers
    /// (the dataset layer's `put_vara`/`get_vara`, the examples) that
    /// need the raw `start`/`count` pair instead of a compiled filetype.
    /// Block distribution: ceil division, trailing processes may get
    /// less; a process whose block is empty is an error, as in
    /// `MPI_Type_create_darray`.
    pub fn block_decompose(
        size_global: &[usize],
        psizes: &[usize],
        rank: usize,
    ) -> Result<(Vec<usize>, Vec<usize>), TypeError> {
        let ndims = size_global.len();
        if psizes.len() != ndims {
            return Err(TypeError::ArgMismatch(format!(
                "darray: {ndims} dims vs {} psizes",
                psizes.len()
            )));
        }
        let nprocs: usize = psizes.iter().product();
        if rank >= nprocs {
            return Err(TypeError::InvalidArg(format!(
                "darray: rank {rank} outside {nprocs}-process grid"
            )));
        }
        // Rank -> grid coordinates (row-major over the process grid).
        let mut coords = vec![0usize; ndims];
        let mut r = rank;
        for d in (0..ndims).rev() {
            coords[d] = r % psizes[d];
            r /= psizes[d];
        }
        let mut subsizes = vec![0usize; ndims];
        let mut starts = vec![0usize; ndims];
        for d in 0..ndims {
            let blk = size_global[d].div_ceil(psizes[d]);
            let s = (coords[d] * blk).min(size_global[d]);
            let e = ((coords[d] + 1) * blk).min(size_global[d]);
            if e <= s {
                return Err(TypeError::InvalidArg(format!(
                    "darray: empty block for rank {rank} in dim {d}"
                )));
            }
            starts[d] = s;
            subsizes[d] = e - s;
        }
        Ok((starts, subsizes))
    }

    /// Change lb/extent — `MPI_Type_create_resized`.
    pub fn resized(base: &Datatype, lb: i64, extent: i64) -> Result<Datatype, TypeError> {
        if extent < 0 {
            return Err(TypeError::InvalidArg("resized: negative extent".into()));
        }
        let map = base.segments();
        let size: usize = map.iter().map(|s| s.len()).sum();
        Ok(Datatype::Derived(Arc::new(Derived {
            map,
            size,
            lb,
            ub: lb + extent,
            name: format!("resized({base},lb={lb},extent={extent})"),
        })))
    }

    /// Duplicate — `MPI_Type_dup` (MPI-2.2 change list item 4).
    pub fn dup(&self) -> Datatype {
        self.clone()
    }

    /// Decode the type map (`MPI_Type_get_contents` analogue, change list
    /// item 5): returns the flattened segments.
    pub fn decode(&self) -> Vec<Segment> {
        self.segments()
    }
}

/// Append one instance of `ty` at byte origin `origin` to `map`.
fn append_instance(map: &mut Vec<Segment>, ty: &Datatype, origin: i64) {
    match ty {
        Datatype::Prim(p) => push_coalesce(map, Segment { offset: origin, prim: *p, count: 1 }),
        Datatype::Derived(d) => {
            for s in &d.map {
                push_coalesce(
                    map,
                    Segment { offset: origin + s.offset, prim: s.prim, count: s.count },
                );
            }
        }
    }
}

/// Push a segment, merging with the previous when contiguous + same prim.
fn push_coalesce(map: &mut Vec<Segment>, s: Segment) {
    if let Some(last) = map.last_mut() {
        if last.prim == s.prim && last.end() == s.offset {
            last.count += s.count;
            return;
        }
    }
    map.push(s);
}

/// Sort/validate the map and wrap it.
fn finish(mut map: Vec<Segment>, lb: i64, ub: i64, name: String) -> Datatype {
    map.sort_by_key(|s| s.offset);
    // Re-coalesce after sorting (constructors may emit out-of-order blocks).
    let mut merged: Vec<Segment> = Vec::with_capacity(map.len());
    for s in map {
        push_coalesce(&mut merged, s);
    }
    let size = merged.iter().map(|s| s.len()).sum();
    Datatype::Derived(Arc::new(Derived { map: merged, size, lb, ub, name }))
}

/// Iterator over the byte runs of `count` instances of a datatype.
pub struct ByteRuns {
    ty: Datatype,
    segments: Vec<Segment>,
    extent: i64,
    count: usize,
    inst: usize,
    seg: usize,
}

impl ByteRuns {
    fn new(ty: Datatype, count: usize) -> Self {
        let segments = ty.segments();
        let extent = ty.extent();
        ByteRuns { ty, segments, extent, count, inst: 0, seg: 0 }
    }

    /// Total payload bytes across all runs.
    pub fn total_bytes(&self) -> usize {
        self.ty.size() * self.count
    }

    /// Collect runs coalescing across primitive boundaries (byte geometry
    /// only). Used when no representation conversion is needed.
    pub fn coalesced(self) -> Vec<(i64, usize)> {
        let mut out: Vec<(i64, usize)> = Vec::new();
        for r in self {
            if let Some(last) = out.last_mut() {
                if last.0 + last.1 as i64 == r.offset {
                    last.1 += r.len();
                    continue;
                }
            }
            out.push((r.offset, r.len()));
        }
        out
    }
}

impl Iterator for ByteRuns {
    type Item = Segment;

    fn next(&mut self) -> Option<Segment> {
        if self.inst >= self.count || self.segments.is_empty() {
            return None;
        }
        let s = self.segments[self.seg];
        let run = Segment {
            offset: s.offset + self.inst as i64 * self.extent,
            prim: s.prim,
            count: s.count,
        };
        self.seg += 1;
        if self.seg == self.segments.len() {
            self.seg = 0;
            self.inst += 1;
        }
        Some(run)
    }
}

// ----------------------------------------------------------------------
// Typed buffer views: lets the API take `&[i32]`, `&[f64]`, ... buffers
// (the paper's `Object buf` parameter) without per-element conversion —
// precisely the capability the paper found missing from java.io (§2.3.1).
// ----------------------------------------------------------------------

/// Read-only typed buffer: exposes raw bytes plus the element primitive.
pub trait IoBuf {
    /// Raw bytes of the buffer.
    fn as_bytes(&self) -> &[u8];
    /// The element primitive.
    fn prim(&self) -> Prim;
    /// Element count.
    fn elems(&self) -> usize;
}

/// Mutable typed buffer.
pub trait IoBufMut: IoBuf {
    /// Raw mutable bytes of the buffer.
    fn as_bytes_mut(&mut self) -> &mut [u8];
}

macro_rules! impl_iobuf {
    ($t:ty, $prim:expr) => {
        impl IoBuf for [$t] {
            fn as_bytes(&self) -> &[u8] {
                // Safety: plain-old-data slices reinterpret as bytes.
                unsafe {
                    std::slice::from_raw_parts(
                        self.as_ptr() as *const u8,
                        std::mem::size_of_val(self),
                    )
                }
            }
            fn prim(&self) -> Prim {
                $prim
            }
            fn elems(&self) -> usize {
                self.len()
            }
        }
        impl IoBufMut for [$t] {
            fn as_bytes_mut(&mut self) -> &mut [u8] {
                unsafe {
                    std::slice::from_raw_parts_mut(
                        self.as_mut_ptr() as *mut u8,
                        std::mem::size_of_val(self),
                    )
                }
            }
        }
    };
}

impl_iobuf!(u8, Prim::Byte);
impl_iobuf!(i16, Prim::Short);
impl_iobuf!(i32, Prim::Int);
impl_iobuf!(i64, Prim::Long);
impl_iobuf!(f32, Prim::Float);
impl_iobuf!(f64, Prim::Double);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Config};

    #[test]
    fn primitive_sizes() {
        assert_eq!(Datatype::INT.size(), 4);
        assert_eq!(Datatype::DOUBLE.size(), 8);
        assert_eq!(Datatype::BYTE.extent(), 1);
        assert!(Datatype::INT.is_contiguous());
    }

    #[test]
    fn contiguous_coalesces_to_one_segment() {
        let t = Datatype::contiguous(10, &Datatype::INT).unwrap();
        assert_eq!(t.size(), 40);
        assert_eq!(t.extent(), 40);
        assert_eq!(t.segment_count(), 1);
        assert!(t.is_contiguous());
    }

    #[test]
    fn vector_has_holes() {
        // 3 blocks of 2 ints, stride 4 ints: |XX..|XX..|XX|
        let t = Datatype::vector(3, 2, 4, &Datatype::INT).unwrap();
        assert_eq!(t.size(), 3 * 2 * 4);
        // extent = (count-1)*stride_bytes + blocklen*elem = 2*16 + 8 = 40
        assert_eq!(t.extent(), 40);
        assert_eq!(t.segment_count(), 3);
        assert!(!t.is_contiguous());
        let segs = t.segments();
        assert_eq!(segs[0], Segment { offset: 0, prim: Prim::Int, count: 2 });
        assert_eq!(segs[1], Segment { offset: 16, prim: Prim::Int, count: 2 });
        assert_eq!(segs[2], Segment { offset: 32, prim: Prim::Int, count: 2 });
    }

    #[test]
    fn vector_blocklen_equal_stride_is_contiguous() {
        let t = Datatype::vector(4, 3, 3, &Datatype::FLOAT).unwrap();
        assert!(t.is_contiguous());
        assert_eq!(t.size(), 48);
    }

    #[test]
    fn indexed_sorts_and_merges() {
        // Blocks at element displacements 4 and 0 of len 2: merge not
        // possible (gap), order normalized.
        let t = Datatype::indexed(&[2, 2], &[4, 0], &Datatype::INT).unwrap();
        let segs = t.segments();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].offset, 0);
        assert_eq!(segs[1].offset, 16);
        // Adjacent displacements merge.
        let t2 = Datatype::indexed(&[2, 2], &[2, 0], &Datatype::INT).unwrap();
        assert_eq!(t2.segment_count(), 1);
        assert!(t2.is_contiguous());
    }

    #[test]
    fn indexed_arg_mismatch_errors() {
        let e = Datatype::indexed(&[1, 2], &[0], &Datatype::INT).unwrap_err();
        assert!(matches!(e, TypeError::ArgMismatch(_)));
    }

    #[test]
    fn struct_heterogeneous() {
        // {int @0, double @8}
        let t = Datatype::struct_(
            &[1, 1],
            &[0, 8],
            &[Datatype::INT, Datatype::DOUBLE],
        )
        .unwrap();
        assert_eq!(t.size(), 12);
        assert_eq!(t.extent(), 16);
        assert!(!t.is_homogeneous());
        assert_eq!(t.base_prim(), Prim::Int);
    }

    #[test]
    fn subarray_2d_row_major() {
        // 4x6 array, take 2x3 block at (1,2).
        let t = Datatype::subarray(&[4, 6], &[2, 3], &[1, 2], ArrayOrder::C, &Datatype::INT)
            .unwrap();
        assert_eq!(t.size(), 2 * 3 * 4);
        assert_eq!(t.extent(), 4 * 6 * 4); // full array extent => holes
        let segs = t.segments();
        assert_eq!(segs.len(), 2); // one run per selected row
        assert_eq!(segs[0].offset, (1 * 6 + 2) * 4);
        assert_eq!(segs[0].count, 3);
        assert_eq!(segs[1].offset, (2 * 6 + 2) * 4);
    }

    #[test]
    fn subarray_full_is_contiguous() {
        let t = Datatype::subarray(&[8, 8], &[8, 8], &[0, 0], ArrayOrder::C, &Datatype::BYTE)
            .unwrap();
        assert!(t.is_contiguous());
        assert_eq!(t.size(), 64);
    }

    #[test]
    fn subarray_fortran_order_matches_transposed_c() {
        // Fortran (column-major) 6x4 array, block 3x2 at (2,1) must equal
        // the C-order subarray of the transposed shape.
        let f = Datatype::subarray(&[6, 4], &[3, 2], &[2, 1], ArrayOrder::Fortran, &Datatype::INT)
            .unwrap();
        let c = Datatype::subarray(&[4, 6], &[2, 3], &[1, 2], ArrayOrder::C, &Datatype::INT)
            .unwrap();
        assert_eq!(f.segments(), c.segments());
    }

    #[test]
    fn subarray_bounds_checked() {
        let e = Datatype::subarray(&[4, 4], &[2, 2], &[3, 0], ArrayOrder::C, &Datatype::INT)
            .unwrap_err();
        assert!(matches!(e, TypeError::SubarrayBounds(_)));
    }

    #[test]
    fn subarray_3d() {
        let t = Datatype::subarray(
            &[4, 4, 4],
            &[2, 2, 4],
            &[0, 2, 0],
            ArrayOrder::C,
            &Datatype::DOUBLE,
        )
        .unwrap();
        assert_eq!(t.size(), 2 * 2 * 4 * 8);
        // Inner dim fully selected and contiguous rows in dim1 merge:
        // rows (i, 2..4, 0..4) for i in 0..2 — within each i the two rows
        // are adjacent (stride 4*8 = row len), so 2 segments remain.
        assert_eq!(t.segment_count(), 2);
    }

    #[test]
    fn darray_blocks_partition_the_array() {
        // 8x8 over a 2x2 grid: each rank gets a 4x4 block; the 4 blocks
        // tile the array exactly.
        let mut covered = vec![false; 64];
        for rank in 0..4 {
            let t = Datatype::darray_block(&[8, 8], &[2, 2], rank, ArrayOrder::C, &Datatype::INT)
                .unwrap();
            assert_eq!(t.size(), 16 * 4);
            for s in t.segments() {
                let start = s.offset as usize / 4;
                for e in start..start + s.count {
                    assert!(!covered[e], "element {e} covered twice");
                    covered[e] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn darray_uneven_division() {
        // 10 elements over 4 procs: blocks of ceil(10/4)=3 -> 3,3,3,1.
        let sizes: Vec<usize> = (0..4)
            .map(|r| {
                Datatype::darray_block(&[10], &[4], r, ArrayOrder::C, &Datatype::INT)
                    .unwrap()
                    .size()
                    / 4
            })
            .collect();
        assert_eq!(sizes, vec![3, 3, 3, 1]);
    }

    #[test]
    fn block_decompose_matches_darray_and_tiles() {
        // The raw (starts, counts) pairs must tile the array exactly —
        // they are the decomposition darray_block compiles.
        let mut covered = vec![false; 6 * 10];
        for rank in 0..4 {
            let (starts, counts) = Datatype::block_decompose(&[6, 10], &[2, 2], rank).unwrap();
            for i in 0..counts[0] {
                for j in 0..counts[1] {
                    let e = (starts[0] + i) * 10 + starts[1] + j;
                    assert!(!covered[e], "element {e} covered twice");
                    covered[e] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
        // Errors: rank off the grid, empty trailing block.
        assert!(Datatype::block_decompose(&[6, 10], &[2, 2], 4).is_err());
        assert!(Datatype::block_decompose(&[2], &[4], 3).is_err());
    }

    #[test]
    fn resized_changes_extent_only() {
        let t = Datatype::contiguous(2, &Datatype::INT).unwrap();
        let r = Datatype::resized(&t, 0, 32).unwrap();
        assert_eq!(r.size(), 8);
        assert_eq!(r.extent(), 32);
        assert_eq!(r.true_extent(), 8);
    }

    #[test]
    fn byte_runs_tile_by_extent() {
        let t = Datatype::vector(2, 1, 2, &Datatype::INT).unwrap(); // X.X
        let runs: Vec<_> = t.byte_runs(2).collect();
        // extent = 12 bytes (2 blocks stride 2 ints => ub = (2-1)*8+4 = 12)
        assert_eq!(t.extent(), 12);
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0].offset, 0);
        assert_eq!(runs[1].offset, 8);
        assert_eq!(runs[2].offset, 12);
        assert_eq!(runs[3].offset, 20);
    }

    #[test]
    fn byte_runs_coalesced_merges_adjacent_instances() {
        let t = Datatype::contiguous(4, &Datatype::INT).unwrap();
        let runs = t.byte_runs(8).coalesced();
        assert_eq!(runs, vec![(0, 128)]);
    }

    #[test]
    fn iobuf_reinterprets_slices() {
        let v: Vec<i32> = vec![1, 2];
        let b = v.as_slice().as_bytes();
        assert_eq!(b.len(), 8);
        assert_eq!(v.as_slice().prim(), Prim::Int);
        let f: Vec<f64> = vec![1.0];
        assert_eq!(f.as_slice().as_bytes().len(), 8);
    }

    // ---------------- property tests ----------------

    #[test]
    fn prop_size_never_exceeds_extent_times_one() {
        forall(
            Config::default().cases(200),
            |r| {
                let count = r.range(1, 8);
                let blocklen = r.range(1, 8);
                let stride = r.range_i64(blocklen as i64, 16);
                (count, blocklen, stride)
            },
            |&(count, blocklen, stride)| {
                let t = Datatype::vector(count, blocklen, stride, &Datatype::INT).unwrap();
                t.size() as i64 <= t.extent() && t.true_extent() <= t.extent()
            },
        );
    }

    #[test]
    fn prop_segments_sorted_disjoint() {
        forall(
            Config::default().cases(200),
            |r| {
                // Non-overlapping blocks: each displacement leaves room for
                // the previous block plus a random gap. (Overlap is legal
                // in MPI, but then the sorted-disjoint property cannot
                // hold, so the generator avoids it.)
                let n = r.range(1, 6);
                let mut disps = Vec::with_capacity(n);
                let mut lens = Vec::with_capacity(n);
                let mut cursor = 0i64;
                for _ in 0..n {
                    let len = r.range(1, 3);
                    let gap = r.range_i64(1, 5);
                    disps.push(cursor + gap);
                    cursor += gap + len as i64;
                    lens.push(len);
                }
                (lens, disps)
            },
            |(lens, disps)| {
                let t = Datatype::indexed(lens, disps, &Datatype::BYTE).unwrap();
                let segs = t.segments();
                segs.windows(2).all(|w| w[0].end() <= w[1].offset)
            },
        );
    }

    #[test]
    fn prop_subarray_size_is_product_of_subsizes() {
        forall(
            Config::default().cases(200),
            |r| {
                let ndims = r.range(1, 3);
                let mut sizes = Vec::new();
                let mut subsizes = Vec::new();
                let mut starts = Vec::new();
                for _ in 0..ndims {
                    let sz = r.range(2, 10);
                    let sub = r.range(1, sz);
                    let st = r.range(0, sz - sub);
                    sizes.push(sz);
                    subsizes.push(sub);
                    starts.push(st);
                }
                (sizes, subsizes, starts)
            },
            |(sizes, subsizes, starts)| {
                let t = Datatype::subarray(sizes, subsizes, starts, ArrayOrder::C, &Datatype::INT)
                    .unwrap();
                let want: usize = subsizes.iter().product::<usize>() * 4;
                let total: usize = sizes.iter().product::<usize>() * 4;
                t.size() == want && t.extent() == total as i64
            },
        );
    }

    #[test]
    fn prop_byte_runs_total_matches_size() {
        forall(
            Config::default().cases(100),
            |r| (r.range(1, 5), r.range(1, 4), r.range_i64(4, 12), r.range(1, 6)),
            |&(c, b, s, count)| {
                let t = Datatype::vector(c, b, s, &Datatype::INT).unwrap();
                let sum: usize = t.byte_runs(count).map(|r| r.len()).sum();
                sum == t.size() * count
            },
        );
    }
}
