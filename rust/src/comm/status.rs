//! Status objects returned by data-access routines (`mpj.Status`).
//!
//! Every blocking read/write in the MPJ-IO spec returns a `Status` from
//! which the element count of the completed transfer can be recovered
//! (`MPI_Get_count` / `MPI_Get_elements`).

use super::datatype::Datatype;

/// Completion record of a point-to-point or file data-access operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Status {
    /// Source rank for receives; the calling rank for file ops.
    pub source: usize,
    /// Message tag for receives; 0 for file ops.
    pub tag: i32,
    /// Bytes actually transferred.
    pub bytes: usize,
}

impl Status {
    /// A status recording a `bytes`-byte file transfer.
    pub fn of_bytes(bytes: usize) -> Status {
        Status { source: 0, tag: 0, bytes }
    }

    /// Number of *complete* datatype instances transferred
    /// (`MPI_Get_count`); `None` if the byte count is not a whole number
    /// of instances.
    pub fn count(&self, datatype: &Datatype) -> Option<usize> {
        let sz = datatype.size();
        if sz == 0 {
            return Some(0);
        }
        (self.bytes % sz == 0).then_some(self.bytes / sz)
    }

    /// Number of primitive elements transferred (`MPI_Get_elements`),
    /// valid for homogeneous datatypes.
    pub fn elements(&self, datatype: &Datatype) -> usize {
        let esz = datatype.base_prim().size();
        self.bytes / esz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Datatype;

    #[test]
    fn count_whole_instances() {
        let s = Status::of_bytes(40);
        assert_eq!(s.count(&Datatype::INT), Some(10));
        let vec = Datatype::vector(2, 2, 3, &Datatype::INT).unwrap(); // size 16
        assert_eq!(s.count(&vec), None); // 40 % 16 != 0
        assert_eq!(Status::of_bytes(32).count(&vec), Some(2));
    }

    #[test]
    fn elements_in_base_prims() {
        let vec = Datatype::vector(2, 2, 3, &Datatype::INT).unwrap();
        assert_eq!(Status::of_bytes(32).elements(&vec), 8);
    }
}
