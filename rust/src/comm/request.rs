//! Nonblocking point-to-point operations (`mpj.Request` for messages).
//!
//! MPJ Express exposes isend/irecv at the device level ("non-blocking and
//! blocking communications at device level", §1.2); jpio's transports are
//! both *buffering* (mailboxes / the socket progress engine), so `isend`
//! completes locally at once and `irecv` is a poll handle over
//! [`Comm::try_recv`].

use super::Comm;

/// Handle for a pending nonblocking receive.
pub struct RecvRequest {
    src: usize,
    tag: i32,
    done: Option<Vec<u8>>,
}

impl RecvRequest {
    /// Start a nonblocking receive (`MPI_Irecv`).
    pub fn new(src: usize, tag: i32) -> RecvRequest {
        RecvRequest { src, tag, done: None }
    }

    /// Poll for completion (`MPI_Test`).
    pub fn test(&mut self, comm: &dyn Comm) -> bool {
        if self.done.is_none() {
            self.done = comm.try_recv(self.src, self.tag);
        }
        self.done.is_some()
    }

    /// Block until the message arrives (`MPI_Wait`).
    pub fn wait(mut self, comm: &dyn Comm) -> Vec<u8> {
        match self.done.take() {
            Some(v) => v,
            None => comm.recv(self.src, self.tag),
        }
    }
}

/// Handle for a nonblocking send. Both transports buffer eagerly, so the
/// send is complete on return; the handle exists for API fidelity.
pub struct SendRequest {
    _completed: (),
}

impl SendRequest {
    /// Completed-send handle.
    pub fn ready() -> SendRequest {
        SendRequest { _completed: () }
    }

    /// Always true (eager buffering).
    pub fn test(&mut self) -> bool {
        true
    }

    /// No-op.
    pub fn wait(self) {}
}

/// Nonblocking extensions over any communicator.
pub trait CommNonblocking: Comm {
    /// `MPI_Isend`: eager-buffered send; completes immediately.
    fn isend(&self, dest: usize, tag: i32, data: &[u8]) -> SendRequest {
        self.send(dest, tag, data);
        SendRequest::ready()
    }

    /// `MPI_Irecv`: returns a pollable receive handle.
    fn irecv(&self, src: usize, tag: i32) -> RecvRequest {
        RecvRequest::new(src, tag)
    }
}

impl<C: Comm + ?Sized> CommNonblocking for C {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;

    #[test]
    fn irecv_polls_until_message_arrives() {
        threads::run(2, |c| {
            if c.rank() == 0 {
                let mut req = c.irecv(1, 5);
                // Poll (may spin a few times before rank 1 sends).
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
                while !req.test(c) {
                    assert!(std::time::Instant::now() < deadline);
                    std::thread::yield_now();
                }
                assert_eq!(req.wait(c), b"polled");
            } else {
                std::thread::sleep(std::time::Duration::from_millis(5));
                let mut s = c.isend(0, 5, b"polled");
                assert!(s.test());
                s.wait();
            }
        });
    }

    #[test]
    fn irecv_wait_blocks_without_polling() {
        threads::run(2, |c| {
            if c.rank() == 0 {
                let req = c.irecv(1, 9);
                assert_eq!(req.wait(c), vec![42u8; 100]);
            } else {
                c.send(0, 9, &[42u8; 100]);
            }
        });
    }

    #[test]
    fn overlapping_irecvs_match_tags() {
        threads::run(2, |c| {
            if c.rank() == 0 {
                let ra = c.irecv(1, 1);
                let rb = c.irecv(1, 2);
                assert_eq!(rb.wait(c), b"two");
                assert_eq!(ra.wait(c), b"one");
            } else {
                c.send(0, 1, b"one");
                c.send(0, 2, b"two");
            }
        });
    }
}
