//! Per-world progress engine: a dedicated thread per rank that drives
//! message exchange and plan execution while the application computes.
//!
//! The MPI-3.1 nonblocking collectives only pay off when the *whole*
//! operation — the alltoall exchange halves as much as the storage I/O —
//! leaves the calling thread. ROMIO reaches that state with an
//! asynchronous progress thread per process; ViPIOS dedicates whole I/O
//! server processes. jpio's analogue is the **progress lane**: each rank
//! of a communicator world owns (lazily) one background thread
//! ([`ProgressEngine`]) plus a `'static` endpoint onto the same rank
//! whose traffic lives in a reserved tag band ([`shifted`]), so the
//! background collective exchange can never match — or steal — the
//! application thread's messages.
//!
//! Three invariants make this safe:
//!
//! * **FIFO per lane.** Each lane's engine executes submitted jobs in
//!   submission order. MPI already requires every rank to issue
//!   collective operations in the same order, so the background
//!   collectives of a world match up exactly like foreground ones.
//! * **Deterministic lane assignment.** With `jpio_progress_threads > 1`
//!   a rank owns several lanes and successive collective operations
//!   round-robin across them ([`crate::io::file::File`] keeps the per-file
//!   operation counter). Because every rank issues collectives in the
//!   same order, operation `k` lands on the *same* lane index everywhere
//!   and the per-lane FIFO keeps its exchange matched, while operations
//!   on different lanes pipeline. Cross-lane effects that must stay
//!   ordered (the storage phase) are sequenced by the engine's
//!   [`OpSequencer`](crate::io::engine::OpSequencer) tickets.
//! * **Disjoint tag bands.** Lane `l`'s endpoint moves every tag by
//!   [`lane_shift`]`(l)`, placing internal-protocol tags below the bands
//!   used by the application thread, user tags, every
//!   [`SubComm`](super::SubComm) context salt, and every *other* lane. A
//!   blocking collective on the application thread can therefore overlap
//!   any number of background exchanges on the same mailboxes/sockets
//!   without interference.
//!
//! The thread transport hands its lanes *native* banded endpoints
//! (tag-shifted shared mailboxes plus a per-lane shared-memory barrier —
//! the same fast path the app lane gets) instead of a generic wrapper;
//! the process transport wraps its socket endpoint in [`shifted_lane`].
//!
//! Transports opt in via [`Comm::progress_lane`]; the default is `None`
//! (e.g. [`SubComm`](super::SubComm) borrows its parent and cannot hand
//! out a `'static` endpoint), in which case nonblocking collectives fall
//! back to running their exchange on the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use super::Comm;

/// Tag displacement of the first progress lane. Chosen so that shifted
/// internal tags (near `i32::MIN/2`) stay above `i32::MIN` for every
/// lane up to [`MAX_LANES`], and so that no shift is a multiple of the
/// sub-communicator context salt (`(context+1) * 2^20`): no salted
/// sub-communicator band and no user tag can alias progress-lane
/// traffic.
const PROGRESS_TAG_SHIFT: i32 = 300 * (1 << 20) + 12_345;

/// Tag-band stride between adjacent lanes. Lane bands keep the
/// `+ 12_345` residue mod `2^20`, so they stay clear of every context
/// salt band no matter the lane index.
const LANE_TAG_STRIDE: i32 = 1 << 20;

/// Upper bound on per-rank progress lanes (`jpio_progress_threads` is
/// clamped here). Keeps the highest lane band comfortably above
/// `i32::MIN` when displacing the internal tag range.
pub const MAX_LANES: usize = 64;

/// The tag displacement of lane `lane` (lane 0 is the classic progress
/// band).
pub(crate) fn lane_shift(lane: usize) -> i32 {
    assert!(lane < MAX_LANES, "progress lane {lane} beyond MAX_LANES");
    PROGRESS_TAG_SHIFT + (lane as i32) * LANE_TAG_STRIDE
}

/// A communicator endpoint whose every tag is displaced into one lane's
/// progress band. Collectives come from the `Comm` defaults, so they
/// route through the shifted `send`/`recv` (never through transport
/// fast paths that assume application-thread identity — transports that
/// can offer the lane a real fast path build a native banded endpoint
/// instead of this wrapper).
struct ShiftedComm {
    inner: Arc<dyn Comm>,
    shift: i32,
}

impl Comm for ShiftedComm {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        self.inner.send(dest, tag - self.shift, data);
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        self.inner.recv(src, tag - self.shift)
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        self.inner.try_recv(src, tag - self.shift)
    }
}

/// Wrap a `'static` per-rank endpoint so all of its traffic lives in
/// lane 0's progress tag band.
pub fn shifted(inner: Arc<dyn Comm>) -> Arc<dyn Comm> {
    shifted_lane(inner, 0)
}

/// Wrap a `'static` per-rank endpoint into lane `lane`'s tag band.
/// Transports without a native banded endpoint call this from their
/// [`Comm::progress_lane_at`] implementation.
pub fn shifted_lane(inner: Arc<dyn Comm>, lane: usize) -> Arc<dyn Comm> {
    Arc::new(ShiftedComm { inner, shift: lane_shift(lane) })
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One rank's background progress thread: a FIFO executor for the
/// off-caller halves of nonblocking collective operations.
///
/// The engine owns only the job *sender*; the worker thread owns the
/// receiver and exits when the engine (and with it the world that stores
/// it) is dropped. Jobs capture everything they need — including their
/// shifted endpoint — so the engine itself keeps no reference back to
/// the world and world teardown cannot cycle.
pub struct ProgressEngine {
    tx: Mutex<mpsc::Sender<Job>>,
    /// Process that spawned the worker. A forked child inherits the
    /// engine struct but not the thread; submitting there would queue
    /// jobs nobody runs, so callers check [`ProgressEngine::usable`]
    /// and fall back to caller-side execution on a mismatch.
    pid: u32,
    queued: AtomicUsize,
    completed: Arc<AtomicUsize>,
}

impl ProgressEngine {
    /// Spawn the rank's progress thread. `name` labels the thread for
    /// debuggers (`jpio-progress-<rank>` by convention).
    pub fn spawn(name: String) -> ProgressEngine {
        let (tx, rx) = mpsc::channel::<Job>();
        let completed = Arc::new(AtomicUsize::new(0));
        let done = completed.clone();
        std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                // FIFO: one job at a time, in submission order — the
                // property that keeps background collectives matched
                // across ranks. A panicking job must not kill the lane:
                // its completion sender drops (so that one Request
                // reports a completer-died error) but the worker lives
                // on for subsequent collectives; the panic itself is
                // still reported by the default hook.
                while let Ok(job) = rx.recv() {
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job()));
                    done.fetch_add(1, Ordering::Release);
                }
            })
            .expect("spawn progress thread");
        ProgressEngine {
            tx: Mutex::new(tx),
            pid: std::process::id(),
            queued: AtomicUsize::new(0),
            completed,
        }
    }

    /// Whether this engine's worker thread exists in the current process
    /// (false in a forked child that inherited the world).
    pub fn usable(&self) -> bool {
        self.pid == std::process::id()
    }

    /// Enqueue a job on the rank's progress thread. Returns `false` —
    /// without running the job — when the worker does not exist in this
    /// process ([`ProgressEngine::usable`]).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        if !self.usable() {
            return false;
        }
        let sent = self.tx.lock().unwrap().send(Box::new(job)).is_ok();
        if sent {
            self.queued.fetch_add(1, Ordering::Release);
        }
        sent
    }

    /// Enqueue a job, or run it inline on the calling thread when the
    /// worker does not exist in this process (a forked child) — for
    /// work that must happen somewhere, like page-cache write-behind
    /// flushes. Returns `true` when the job was backgrounded.
    pub fn submit_or_run(&self, job: impl FnOnce() + Send + 'static) -> bool {
        if !self.usable() {
            job();
            return false;
        }
        // The worker owns the receiver for as long as the engine (and
        // its sender) lives, so this send cannot fail here; run inline
        // on the impossible path anyway rather than dropping the job.
        match self.tx.lock().unwrap().send(Box::new(job)) {
            Ok(()) => {
                self.queued.fetch_add(1, Ordering::Release);
                true
            }
            Err(mpsc::SendError(job)) => {
                job();
                false
            }
        }
    }

    /// Drain the lane: block until every job submitted before this call
    /// has finished (FIFO worker, so a marker job completing means all
    /// predecessors completed). No-op in a process without the worker.
    pub fn quiesce(&self) {
        if !self.usable() {
            return;
        }
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let sent = self.submit(move || {
            let _ = done_tx.send(());
        });
        if sent {
            let _ = done_rx.recv();
        }
    }

    /// Job counters — `queued > completed` means work is in flight on
    /// the progress thread.
    pub fn stats(&self) -> crate::io::stats::ProgressStats {
        crate::io::stats::ProgressStats {
            queued: self.queued.load(Ordering::Acquire),
            completed: self.completed.load(Ordering::Acquire),
        }
    }
}

/// The process-wide background *maintenance* lane: a single shared
/// [`ProgressEngine`] for storage housekeeping that belongs to no
/// particular communicator world — redundancy rebuilds and restriping
/// migrations submitted by the striped backend. Spawned on first use
/// (thread `jpio-maintenance`) and respawned transparently after a
/// `fork` (the child inherits the struct but not the thread, exactly
/// like the page cache's flush lane).
pub fn maintenance_engine() -> Arc<ProgressEngine> {
    static LANE: std::sync::OnceLock<Mutex<Option<Arc<ProgressEngine>>>> =
        std::sync::OnceLock::new();
    let cell = LANE.get_or_init(|| Mutex::new(None));
    let mut slot = cell.lock().unwrap();
    if let Some(e) = slot.as_ref() {
        if e.usable() {
            return e.clone();
        }
    }
    let e = Arc::new(ProgressEngine::spawn("jpio-maintenance".into()));
    *slot = Some(e.clone());
    e
}

/// One rank's progress lane: the FIFO background executor plus the
/// `'static` banded endpoint its jobs exchange messages through.
///
/// The endpoint is constructed fresh per call (it holds the world
/// alive only as long as a job captures it); the engine is the world's
/// lazily-spawned singleton for this (rank, lane) pair.
pub struct ProgressLane {
    /// The lane's background executor.
    pub engine: Arc<ProgressEngine>,
    /// A `'static` endpoint onto the same rank, in the lane's tag band.
    pub comm: Arc<dyn Comm>,
}

/// One rank's bank of lane engines, spawned lazily per lane index
/// (thread `jpio-progress-<rank>.<lane>`). Engines hold only a job
/// sender, never the world, so idle banks tear down with the world.
pub(crate) struct LaneBank {
    engines: Mutex<Vec<Arc<ProgressEngine>>>,
}

impl LaneBank {
    /// An empty bank (no threads until the first lane is requested).
    pub(crate) fn new() -> LaneBank {
        LaneBank { engines: Mutex::new(Vec::new()) }
    }

    /// The engine of lane `lane`, spawning it (and any lower lanes) on
    /// first use.
    pub(crate) fn engine(&self, rank: usize, lane: usize) -> Arc<ProgressEngine> {
        assert!(lane < MAX_LANES, "progress lane {lane} beyond MAX_LANES");
        let mut v = self.engines.lock().unwrap();
        while v.len() <= lane {
            let l = v.len();
            v.push(Arc::new(ProgressEngine::spawn(format!("jpio-progress-{rank}.{l}"))));
        }
        v[lane].clone()
    }
}

/// Build a rank's lane from its world bank: spawn the engine on first
/// use, wrap the fresh `'static` `endpoint` into the lane's tag band.
/// Transports with a native banded endpoint (the thread transport)
/// build the [`ProgressLane`] themselves instead.
pub(crate) fn lane(
    bank: &LaneBank,
    rank: usize,
    lane: usize,
    endpoint: Arc<dyn Comm>,
) -> ProgressLane {
    ProgressLane { engine: bank.engine(rank, lane), comm: shifted_lane(endpoint, lane) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;

    #[test]
    fn engine_runs_jobs_in_submission_order() {
        let engine = ProgressEngine::spawn("jpio-progress-test".into());
        assert!(engine.usable());
        let (tx, rx) = mpsc::channel();
        for i in 0..16 {
            let tx = tx.clone();
            assert!(engine.submit(move || {
                let _ = tx.send(i);
            }));
        }
        let got: Vec<i32> = (0..16).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, (0..16).collect::<Vec<_>>(), "jobs must run FIFO");
        let s = engine.stats();
        assert_eq!(s.queued, 16);
        assert!(s.completed <= 16);
    }

    #[test]
    fn shifted_endpoint_does_not_steal_app_traffic() {
        threads::run(2, |c| {
            let lane = c.progress_lane().expect("thread transport has a lane");
            if c.rank() == 0 {
                // Same (peer, tag) on both lanes: each message must be
                // delivered to the lane it was sent on.
                c.send(1, 7, b"app");
                lane.comm.send(1, 7, b"progress");
            } else {
                let lane_msg = lane.comm.recv(0, 7);
                let app_msg = c.recv(0, 7);
                assert_eq!(lane_msg, b"progress");
                assert_eq!(app_msg, b"app");
            }
        });
    }

    #[test]
    fn background_collectives_run_while_app_thread_waits() {
        // Every rank submits the same collective job; the progress
        // threads rendezvous among themselves (message-based barrier +
        // allgather in the shifted band) while the app threads block on
        // the result channel.
        threads::run(3, |c| {
            let lane = c.progress_lane().unwrap();
            let (tx, rx) = mpsc::channel();
            let comm = lane.comm.clone();
            assert!(lane.engine.submit(move || {
                comm.barrier();
                let parts = comm.allgather(&[comm.rank() as u8]);
                let _ = tx.send(parts);
            }));
            let parts = rx.recv().unwrap();
            assert_eq!(parts, vec![vec![0u8], vec![1u8], vec![2u8]]);
        });
    }
}
