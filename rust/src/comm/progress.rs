//! Per-world progress engine: a dedicated thread per rank that drives
//! message exchange and plan execution while the application computes.
//!
//! The MPI-3.1 nonblocking collectives only pay off when the *whole*
//! operation — the alltoall exchange halves as much as the storage I/O —
//! leaves the calling thread. ROMIO reaches that state with an
//! asynchronous progress thread per process; ViPIOS dedicates whole I/O
//! server processes. jpio's analogue is the **progress lane**: each rank
//! of a communicator world owns (lazily) one background thread
//! ([`ProgressEngine`]) plus a `'static` endpoint onto the same rank
//! whose traffic lives in a reserved tag band ([`shifted`]), so the
//! background collective exchange can never match — or steal — the
//! application thread's messages.
//!
//! Two invariants make this safe:
//!
//! * **FIFO per rank.** Each rank's engine executes submitted jobs in
//!   submission order. MPI already requires every rank to issue
//!   collective operations in the same order, so the background
//!   collectives of a world match up exactly like foreground ones.
//! * **Disjoint tag bands.** The shifted endpoint moves every tag by
//!   `PROGRESS_TAG_SHIFT`, placing internal-protocol tags below the
//!   bands used by the application thread, user tags, and every
//!   [`SubComm`](super::SubComm) context salt. A blocking collective on
//!   the application thread can therefore overlap a background exchange
//!   on the same mailboxes/sockets without interference. The shifted
//!   endpoint also never touches transport fast paths with no sender
//!   identity (e.g. the thread transport's native barrier): it inherits
//!   the default message-based collectives, which route through the
//!   shifted tags.
//!
//! Transports opt in via [`Comm::progress_lane`]; the default is `None`
//! (e.g. [`SubComm`](super::SubComm) borrows its parent and cannot hand
//! out a `'static` endpoint), in which case nonblocking collectives fall
//! back to running their exchange on the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use once_cell::sync::OnceCell;

use super::Comm;

/// Tag displacement of the progress lane. Chosen so that shifted
/// internal tags (near `i32::MIN/2`) stay above `i32::MIN`, and so the
/// shift is not a multiple of the sub-communicator context salt
/// (`(context+1) * 2^20`): no salted sub-communicator band and no user
/// tag can alias progress-lane traffic.
const PROGRESS_TAG_SHIFT: i32 = 300 * (1 << 20) + 12_345;

/// A communicator endpoint whose every tag is displaced into the
/// progress band. Collectives come from the `Comm` defaults, so they
/// route through the shifted `send`/`recv` (never through transport
/// fast paths that assume application-thread identity).
struct ShiftedComm {
    inner: Arc<dyn Comm>,
}

impl Comm for ShiftedComm {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        self.inner.send(dest, tag - PROGRESS_TAG_SHIFT, data);
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        self.inner.recv(src, tag - PROGRESS_TAG_SHIFT)
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        self.inner.try_recv(src, tag - PROGRESS_TAG_SHIFT)
    }
}

/// Wrap a `'static` per-rank endpoint so all of its traffic lives in the
/// progress tag band. Transports call this from their
/// [`Comm::progress_lane`] implementation.
pub fn shifted(inner: Arc<dyn Comm>) -> Arc<dyn Comm> {
    Arc::new(ShiftedComm { inner })
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One rank's background progress thread: a FIFO executor for the
/// off-caller halves of nonblocking collective operations.
///
/// The engine owns only the job *sender*; the worker thread owns the
/// receiver and exits when the engine (and with it the world that stores
/// it) is dropped. Jobs capture everything they need — including their
/// shifted endpoint — so the engine itself keeps no reference back to
/// the world and world teardown cannot cycle.
pub struct ProgressEngine {
    tx: Mutex<mpsc::Sender<Job>>,
    /// Process that spawned the worker. A forked child inherits the
    /// engine struct but not the thread; submitting there would queue
    /// jobs nobody runs, so callers check [`ProgressEngine::usable`]
    /// and fall back to caller-side execution on a mismatch.
    pid: u32,
    queued: AtomicUsize,
    completed: Arc<AtomicUsize>,
}

impl ProgressEngine {
    /// Spawn the rank's progress thread. `name` labels the thread for
    /// debuggers (`jpio-progress-<rank>` by convention).
    pub fn spawn(name: String) -> ProgressEngine {
        let (tx, rx) = mpsc::channel::<Job>();
        let completed = Arc::new(AtomicUsize::new(0));
        let done = completed.clone();
        std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                // FIFO: one job at a time, in submission order — the
                // property that keeps background collectives matched
                // across ranks. A panicking job must not kill the lane:
                // its completion sender drops (so that one Request
                // reports a completer-died error) but the worker lives
                // on for subsequent collectives; the panic itself is
                // still reported by the default hook.
                while let Ok(job) = rx.recv() {
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job()));
                    done.fetch_add(1, Ordering::Release);
                }
            })
            .expect("spawn progress thread");
        ProgressEngine {
            tx: Mutex::new(tx),
            pid: std::process::id(),
            queued: AtomicUsize::new(0),
            completed,
        }
    }

    /// Whether this engine's worker thread exists in the current process
    /// (false in a forked child that inherited the world).
    pub fn usable(&self) -> bool {
        self.pid == std::process::id()
    }

    /// Enqueue a job on the rank's progress thread. Returns `false` —
    /// without running the job — when the worker does not exist in this
    /// process ([`ProgressEngine::usable`]).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        if !self.usable() {
            return false;
        }
        let sent = self.tx.lock().unwrap().send(Box::new(job)).is_ok();
        if sent {
            self.queued.fetch_add(1, Ordering::Release);
        }
        sent
    }

    /// Job counters — `queued > completed` means work is in flight on
    /// the progress thread.
    pub fn stats(&self) -> crate::io::stats::ProgressStats {
        crate::io::stats::ProgressStats {
            queued: self.queued.load(Ordering::Acquire),
            completed: self.completed.load(Ordering::Acquire),
        }
    }
}

/// One rank's progress lane: the FIFO background executor plus the
/// `'static` shifted endpoint its jobs exchange messages through.
///
/// The endpoint is constructed fresh per call (it holds the world
/// alive only as long as a job captures it); the engine is the world's
/// lazily-spawned singleton for this rank.
pub struct ProgressLane {
    /// The rank's background executor.
    pub engine: Arc<ProgressEngine>,
    /// A `'static` endpoint onto the same rank, in the progress tag band.
    pub comm: Arc<dyn Comm>,
}

/// Build a rank's lane from its world slot: spawn the engine on first
/// use (one per rank, `jpio-progress-<rank>`), wrap the fresh `'static`
/// `endpoint` into the shifted tag band. The one place the lane
/// contract lives — both transports delegate here.
pub(crate) fn lane(
    slot: &OnceCell<Arc<ProgressEngine>>,
    rank: usize,
    endpoint: Arc<dyn Comm>,
) -> ProgressLane {
    let engine = slot
        .get_or_init(|| Arc::new(ProgressEngine::spawn(format!("jpio-progress-{rank}"))))
        .clone();
    ProgressLane { engine, comm: shifted(endpoint) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::threads;

    #[test]
    fn engine_runs_jobs_in_submission_order() {
        let engine = ProgressEngine::spawn("jpio-progress-test".into());
        assert!(engine.usable());
        let (tx, rx) = mpsc::channel();
        for i in 0..16 {
            let tx = tx.clone();
            assert!(engine.submit(move || {
                let _ = tx.send(i);
            }));
        }
        let got: Vec<i32> = (0..16).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, (0..16).collect::<Vec<_>>(), "jobs must run FIFO");
        let s = engine.stats();
        assert_eq!(s.queued, 16);
        assert!(s.completed <= 16);
    }

    #[test]
    fn shifted_endpoint_does_not_steal_app_traffic() {
        threads::run(2, |c| {
            let lane = c.progress_lane().expect("thread transport has a lane");
            if c.rank() == 0 {
                // Same (peer, tag) on both lanes: each message must be
                // delivered to the lane it was sent on.
                c.send(1, 7, b"app");
                lane.comm.send(1, 7, b"progress");
            } else {
                let lane_msg = lane.comm.recv(0, 7);
                let app_msg = c.recv(0, 7);
                assert_eq!(lane_msg, b"progress");
                assert_eq!(app_msg, b"app");
            }
        });
    }

    #[test]
    fn background_collectives_run_while_app_thread_waits() {
        // Every rank submits the same collective job; the progress
        // threads rendezvous among themselves (message-based barrier +
        // allgather in the shifted band) while the app threads block on
        // the result channel.
        threads::run(3, |c| {
            let lane = c.progress_lane().unwrap();
            let (tx, rx) = mpsc::channel();
            let comm = lane.comm.clone();
            assert!(lane.engine.submit(move || {
                comm.barrier();
                let parts = comm.allgather(&[comm.rank() as u8]);
                let _ = tx.send(parts);
            }));
            let parts = rx.recv().unwrap();
            assert_eq!(parts, vec![vec![0u8], vec![1u8], vec![2u8]]);
        });
    }
}
