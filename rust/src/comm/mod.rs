//! MPI-like communicator substrate — the MPJ Express analogue.
//!
//! The paper's prototype sits on MPJ Express; this module is the
//! corresponding messaging layer built from scratch: groups, point-to-point
//! send/recv with tags, and the collectives the I/O layer needs (barrier,
//! bcast, gather, allgather, reduce, scan, alltoall), over two transports:
//!
//! * [`threads`] — shared-memory "ranks" as threads of one process (the
//!   paper's shared-memory machine configuration, Figures 4-3/4-4);
//! * [`process`] — ranks as forked processes over Unix sockets (the
//!   paper's distributed-memory MPJ Express configuration, Figure 4-5),
//!   with an interconnect performance model in [`netmodel`].
//!
//! Collectives are implemented as default trait methods over send/recv, so
//! both transports share one verified implementation; `ThreadComm`
//! overrides the latency-critical ones with shared-memory fast paths.

pub mod datatype;
pub mod group;
pub mod netmodel;
pub mod process;
pub mod progress;
pub mod request;
pub mod status;
pub mod sub;
pub mod threads;

pub use datatype::{ArrayOrder, Datatype, Offset, Prim};
pub use group::Group;
pub use progress::{ProgressEngine, ProgressLane};
pub use request::{CommNonblocking, RecvRequest, SendRequest};
pub use status::Status;
pub use sub::SubComm;

/// Tags below this value are reserved for library-internal protocols
/// (collectives, shared-file-pointer service, collective I/O exchange).
pub const INTERNAL_TAG_BASE: i32 = i32::MIN / 2;

/// Internal tag for collective plumbing.
const T_COLL: i32 = INTERNAL_TAG_BASE + 1;
/// Internal tag for barrier rounds.
const T_BARRIER: i32 = INTERNAL_TAG_BASE + 2;

/// Reduction operators for the numeric collectives.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReduceOp {
    /// Sum.
    Sum,
    /// Minimum.
    Min,
    /// Maximum.
    Max,
}

impl ReduceOp {
    fn fold_i64(self, a: i64, b: i64) -> i64 {
        match self {
            ReduceOp::Sum => a.wrapping_add(b),
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }

    fn fold_f64(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

/// An intracommunicator: a fixed group of ranks with point-to-point
/// messaging and collectives (the paper's `Intracomm`, which hosts the
/// collective `fileOpen`/`fileClose` operations).
pub trait Comm: Send + Sync {
    /// This process's rank in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn size(&self) -> usize;

    /// Blocking tagged send of a byte message to `dest`.
    fn send(&self, dest: usize, tag: i32, data: &[u8]);

    /// Blocking tagged receive from `src`. Messages from a given source
    /// are delivered in send order; non-matching tags are queued.
    fn recv(&self, src: usize, tag: i32) -> Vec<u8>;

    /// Nonblocking probe-and-receive: `Some(payload)` if a matching
    /// message is already available (`MPI_Iprobe` + recv).
    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>>;

    /// Synchronize all ranks. Default: flat gather-to-0 + broadcast,
    /// which the transports may override.
    fn barrier(&self) {
        let n = self.size();
        if n <= 1 {
            return;
        }
        if self.rank() == 0 {
            for src in 1..n {
                let _ = self.recv(src, T_BARRIER);
            }
            for dst in 1..n {
                self.send(dst, T_BARRIER, &[]);
            }
        } else {
            self.send(0, T_BARRIER, &[]);
            let _ = self.recv(0, T_BARRIER);
        }
    }

    /// Broadcast `data` from `root` to all ranks (binomial tree).
    fn bcast(&self, root: usize, data: &mut Vec<u8>) {
        let n = self.size();
        if n <= 1 {
            return;
        }
        // Rotate ranks so the root is virtual rank 0.
        let vrank = (self.rank() + n - root) % n;
        let mut mask = 1usize;
        // Receive phase: find the bit where we get the message.
        while mask < n {
            if vrank & mask != 0 {
                let src = (vrank - mask + root) % n;
                *data = self.recv(src, T_COLL);
                break;
            }
            mask <<= 1;
        }
        // Send phase: forward to higher virtual ranks.
        mask >>= 1;
        while mask > 0 {
            if vrank & mask == 0 && vrank + mask < n {
                let dst = (vrank + mask + root) % n;
                self.send(dst, T_COLL, data);
            }
            mask >>= 1;
        }
    }

    /// Gather each rank's bytes at `root`; returns `Some(per-rank vec)` at
    /// the root, `None` elsewhere.
    fn gather(&self, root: usize, data: &[u8]) -> Option<Vec<Vec<u8>>> {
        let n = self.size();
        if self.rank() == root {
            let mut out = vec![Vec::new(); n];
            out[root] = data.to_vec();
            for src in 0..n {
                if src != root {
                    out[src] = self.recv(src, T_COLL);
                }
            }
            Some(out)
        } else {
            self.send(root, T_COLL, data);
            None
        }
    }

    /// All ranks receive every rank's bytes (gather + bcast of a framed
    /// concatenation).
    fn allgather(&self, data: &[u8]) -> Vec<Vec<u8>> {
        let n = self.size();
        if n == 1 {
            return vec![data.to_vec()];
        }
        if let Some(parts) = self.gather(0, data) {
            let mut framed = frame(&parts);
            self.bcast(0, &mut framed);
            parts
        } else {
            let mut framed = Vec::new();
            self.bcast(0, &mut framed);
            unframe(&framed, n)
        }
    }

    /// Scatter per-rank byte payloads from `root`.
    fn scatter(&self, root: usize, data: Option<&[Vec<u8>]>) -> Vec<u8> {
        let n = self.size();
        if self.rank() == root {
            let parts = data.expect("root must supply scatter payloads");
            assert_eq!(parts.len(), n, "scatter payload count != comm size");
            for (dst, part) in parts.iter().enumerate() {
                if dst != root {
                    self.send(dst, T_COLL, part);
                }
            }
            parts[root].clone()
        } else {
            self.recv(root, T_COLL)
        }
    }

    /// Personalized all-to-all: `parts[d]` goes to rank `d`; returns the
    /// payloads received from every rank. Sends are rank-ordered with a
    /// pairwise schedule to avoid head-of-line blocking.
    fn alltoall(&self, parts: &[Vec<u8>]) -> Vec<Vec<u8>> {
        let n = self.size();
        assert_eq!(parts.len(), n, "alltoall payload count != comm size");
        let me = self.rank();
        let mut out = vec![Vec::new(); n];
        out[me] = parts[me].clone();
        // Ring schedule: round r sends to (me+r) and receives from (me-r).
        // Sends are buffered on both transports (mailboxes / progress
        // engine), so send-then-recv cannot deadlock.
        for r in 1..n {
            let send_to = (me + r) % n;
            let recv_from = (me + n - r) % n;
            self.send(send_to, T_COLL, &parts[send_to]);
            out[recv_from] = self.recv(recv_from, T_COLL);
        }
        out
    }

    /// All-reduce of one i64 (gather/bcast through rank 0).
    fn allreduce_i64(&self, op: ReduceOp, value: i64) -> i64 {
        let parts = self.allgather(&value.to_le_bytes());
        parts
            .iter()
            .map(|b| i64::from_le_bytes(b[..8].try_into().unwrap()))
            .reduce(|a, b| op.fold_i64(a, b))
            .unwrap()
    }

    /// All-reduce of one f64.
    fn allreduce_f64(&self, op: ReduceOp, value: f64) -> f64 {
        let parts = self.allgather(&value.to_le_bytes());
        parts
            .iter()
            .map(|b| f64::from_le_bytes(b[..8].try_into().unwrap()))
            .reduce(|a, b| op.fold_f64(a, b))
            .unwrap()
    }

    /// Inclusive prefix scan of one i64 (rank r receives fold of ranks
    /// `0..=r`). Used by the ordered shared-file-pointer collectives.
    fn scan_i64(&self, op: ReduceOp, value: i64) -> i64 {
        let parts = self.allgather(&value.to_le_bytes());
        parts[..=self.rank()]
            .iter()
            .map(|b| i64::from_le_bytes(b[..8].try_into().unwrap()))
            .reduce(|a, b| op.fold_i64(a, b))
            .unwrap()
    }

    /// Exclusive prefix sum of one i64 (rank r receives sum of ranks
    /// `0..r`; rank 0 receives `0`).
    fn exscan_sum_i64(&self, value: i64) -> i64 {
        self.scan_i64(ReduceOp::Sum, value) - value
    }

    /// The group of this communicator.
    fn group(&self) -> Group {
        Group::new((0..self.size()).collect())
    }

    /// This rank's progress lane — a per-world background thread plus a
    /// `'static` endpoint in a reserved tag band ([`progress`]) — used by
    /// the I/O layer to run nonblocking collective operations entirely
    /// off the calling thread. Transports that cannot hand out a
    /// `'static` endpoint (e.g. the borrowing [`SubComm`]) return `None`
    /// and nonblocking collectives fall back to caller-side exchange.
    /// The capability must be uniform across a world: every rank of a
    /// given communicator answers the same way.
    fn progress_lane(&self) -> Option<ProgressLane> {
        None
    }
}

/// Frame a list of byte payloads into one buffer (u32 count, u64 lengths).
pub(crate) fn frame(parts: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + parts.iter().map(|p| p.len() + 8).sum::<usize>());
    out.extend_from_slice(&(parts.len() as u32).to_le_bytes());
    for p in parts {
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
    }
    for p in parts {
        out.extend_from_slice(p);
    }
    out
}

/// Inverse of [`frame`]; `expect` validates the part count.
pub(crate) fn unframe(buf: &[u8], expect: usize) -> Vec<Vec<u8>> {
    let count = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    assert_eq!(count, expect, "unframe: part count mismatch");
    let mut lens = Vec::with_capacity(count);
    let mut pos = 4;
    for _ in 0..count {
        lens.push(u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize);
        pos += 8;
    }
    let mut out = Vec::with_capacity(count);
    for len in lens {
        out.push(buf[pos..pos + len].to_vec());
        pos += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let parts = vec![vec![1u8, 2], vec![], vec![3u8; 100]];
        assert_eq!(unframe(&frame(&parts), 3), parts);
    }

    // The collectives themselves are exercised across transports in
    // threads.rs / process.rs tests and in rust/tests/comm_collectives.rs.
}
