//! MPI-like communicator substrate — the MPJ Express analogue.
//!
//! The paper's prototype sits on MPJ Express; this module is the
//! corresponding messaging layer built from scratch: groups, point-to-point
//! send/recv with tags, and the collectives the I/O layer needs (barrier,
//! bcast, gather, allgather, reduce, scan, alltoall), over two transports:
//!
//! * [`threads`] — shared-memory "ranks" as threads of one process (the
//!   paper's shared-memory machine configuration, Figures 4-3/4-4);
//! * [`process`] — ranks as forked processes over Unix sockets (the
//!   paper's distributed-memory MPJ Express configuration, Figure 4-5),
//!   with an interconnect performance model in [`netmodel`].
//!
//! Collectives are implemented as default trait methods over send/recv, so
//! both transports share one verified implementation; `ThreadComm`
//! overrides the latency-critical ones with shared-memory fast paths.

pub mod datatype;
pub mod group;
pub mod netmodel;
pub mod process;
pub mod progress;
pub mod request;
pub mod status;
pub mod sub;
pub mod threads;

pub use datatype::{ArrayOrder, Datatype, Offset, Prim};
pub use group::Group;
pub use progress::{ProgressEngine, ProgressLane};
pub use request::{CommNonblocking, RecvRequest, SendRequest};
pub use status::Status;
pub use sub::SubComm;

/// Tags below this value are reserved for library-internal protocols
/// (collectives, shared-file-pointer service, collective I/O exchange).
pub const INTERNAL_TAG_BASE: i32 = i32::MIN / 2;

/// Internal tag for collective plumbing.
const T_COLL: i32 = INTERNAL_TAG_BASE + 1;
/// Internal tag for barrier rounds.
const T_BARRIER: i32 = INTERNAL_TAG_BASE + 2;

/// All-to-all exchange algorithm (ROMIO/MPICH-style selection). The
/// personalized exchange is the hot phase of two-phase collective I/O
/// (Thakur et al.), so the schedule matters as soon as worlds grow:
///
/// | algorithm  | rounds      | bytes on the wire | sweet spot              |
/// |------------|-------------|-------------------|-------------------------|
/// | `Linear`   | `n - 1`     | `sum(parts)`      | small worlds            |
/// | `Pairwise` | `n - 1`     | `sum(parts)`      | large messages          |
/// | `Bruck`    | `ceil(lg n)`| `~sum/2 * lg n`   | many ranks, small parts |
///
/// `Auto` picks by rank count and message size ([`AUTO_SCALABLE_RANKS`],
/// [`BRUCK_MSG_CUTOFF`]). Parsed from the `jpio_alltoall_algorithm` hint;
/// malformed values fall back to `Auto` (MPI hint semantics).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum AlltoallAlgorithm {
    /// Select by rank threshold and message size.
    #[default]
    Auto,
    /// Ring schedule: round `r` sends to `me+r`, receives from `me-r`.
    Linear,
    /// Pairwise exchange: XOR partners on power-of-two worlds (each round
    /// is one symmetric sendrecv), ring rotation otherwise.
    Pairwise,
    /// Bruck's algorithm: `ceil(lg n)` store-and-forward rounds of framed
    /// block bundles — each block travels up to `lg n` hops, so total
    /// traffic grows, but the round count (and with it latency and
    /// endpoint pressure) drops from `n-1` to `lg n`.
    Bruck,
}

/// Worlds below this size always use the linear schedule under
/// [`AlltoallAlgorithm::Auto`] — the scalable schedules only pay off once
/// the `n - 1` round count hurts.
pub const AUTO_SCALABLE_RANKS: usize = 8;

/// Largest per-destination payload (bytes) for which `Auto` picks Bruck
/// on scalable worlds; above it the log-factor wire inflation outweighs
/// the round-count win and pairwise exchange is used instead.
pub const BRUCK_MSG_CUTOFF: usize = 4096;

impl AlltoallAlgorithm {
    /// Parse a `jpio_alltoall_algorithm` hint value. Unknown or absent
    /// values select `Auto` (hints must never fail).
    pub fn parse(value: Option<&str>) -> AlltoallAlgorithm {
        match value {
            Some("linear") => AlltoallAlgorithm::Linear,
            Some("pairwise") => AlltoallAlgorithm::Pairwise,
            Some("bruck") => AlltoallAlgorithm::Bruck,
            _ => AlltoallAlgorithm::Auto,
        }
    }

    /// Resolve `Auto` against a concrete exchange shape.
    fn resolve(self, n: usize, max_part: usize) -> AlltoallAlgorithm {
        match self {
            AlltoallAlgorithm::Auto => {
                if n < AUTO_SCALABLE_RANKS {
                    AlltoallAlgorithm::Linear
                } else if max_part <= BRUCK_MSG_CUTOFF {
                    AlltoallAlgorithm::Bruck
                } else {
                    AlltoallAlgorithm::Pairwise
                }
            }
            other => other,
        }
    }
}

/// Reduction operators for the numeric collectives.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ReduceOp {
    /// Sum.
    Sum,
    /// Minimum.
    Min,
    /// Maximum.
    Max,
}

impl ReduceOp {
    fn fold_i64(self, a: i64, b: i64) -> i64 {
        match self {
            ReduceOp::Sum => a.wrapping_add(b),
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }

    fn fold_f64(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

/// An intracommunicator: a fixed group of ranks with point-to-point
/// messaging and collectives (the paper's `Intracomm`, which hosts the
/// collective `fileOpen`/`fileClose` operations).
pub trait Comm: Send + Sync {
    /// This process's rank in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the communicator.
    fn size(&self) -> usize;

    /// Blocking tagged send of a byte message to `dest`.
    fn send(&self, dest: usize, tag: i32, data: &[u8]);

    /// Blocking tagged receive from `src`. Messages from a given source
    /// are delivered in send order; non-matching tags are queued.
    fn recv(&self, src: usize, tag: i32) -> Vec<u8>;

    /// Nonblocking probe-and-receive: `Some(payload)` if a matching
    /// message is already available (`MPI_Iprobe` + recv).
    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>>;

    /// Synchronize all ranks. Default: flat gather-to-0 + broadcast,
    /// which the transports may override.
    fn barrier(&self) {
        let n = self.size();
        if n <= 1 {
            return;
        }
        if self.rank() == 0 {
            for src in 1..n {
                let _ = self.recv(src, T_BARRIER);
            }
            for dst in 1..n {
                self.send(dst, T_BARRIER, &[]);
            }
        } else {
            self.send(0, T_BARRIER, &[]);
            let _ = self.recv(0, T_BARRIER);
        }
    }

    /// Broadcast `data` from `root` to all ranks (binomial tree).
    fn bcast(&self, root: usize, data: &mut Vec<u8>) {
        let n = self.size();
        if n <= 1 {
            return;
        }
        // Rotate ranks so the root is virtual rank 0.
        let vrank = (self.rank() + n - root) % n;
        let mut mask = 1usize;
        // Receive phase: find the bit where we get the message.
        while mask < n {
            if vrank & mask != 0 {
                let src = (vrank - mask + root) % n;
                *data = self.recv(src, T_COLL);
                break;
            }
            mask <<= 1;
        }
        // Send phase: forward to higher virtual ranks.
        mask >>= 1;
        while mask > 0 {
            if vrank & mask == 0 && vrank + mask < n {
                let dst = (vrank + mask + root) % n;
                self.send(dst, T_COLL, data);
            }
            mask >>= 1;
        }
    }

    /// Gather each rank's bytes at `root`; returns `Some(per-rank vec)` at
    /// the root, `None` elsewhere.
    fn gather(&self, root: usize, data: &[u8]) -> Option<Vec<Vec<u8>>> {
        let n = self.size();
        if self.rank() == root {
            let mut out = vec![Vec::new(); n];
            out[root] = data.to_vec();
            for src in 0..n {
                if src != root {
                    out[src] = self.recv(src, T_COLL);
                }
            }
            Some(out)
        } else {
            self.send(root, T_COLL, data);
            None
        }
    }

    /// All ranks receive every rank's bytes (gather + bcast of a framed
    /// concatenation).
    fn allgather(&self, data: &[u8]) -> Vec<Vec<u8>> {
        let n = self.size();
        if n == 1 {
            return vec![data.to_vec()];
        }
        if let Some(parts) = self.gather(0, data) {
            let mut framed = frame(&parts);
            self.bcast(0, &mut framed);
            parts
        } else {
            let mut framed = Vec::new();
            self.bcast(0, &mut framed);
            unframe(&framed, n)
        }
    }

    /// Scatter per-rank byte payloads from `root`.
    fn scatter(&self, root: usize, data: Option<&[Vec<u8>]>) -> Vec<u8> {
        let n = self.size();
        if self.rank() == root {
            let parts = data.expect("root must supply scatter payloads");
            assert_eq!(parts.len(), n, "scatter payload count != comm size");
            for (dst, part) in parts.iter().enumerate() {
                if dst != root {
                    self.send(dst, T_COLL, part);
                }
            }
            parts[root].clone()
        } else {
            self.recv(root, T_COLL)
        }
    }

    /// Combined send-to-`dest` + receive-from-`src` — the round primitive
    /// of the pairwise exchange schedules. The symmetric self case
    /// (`dest == src == rank`) never touches the transport: the payload
    /// is returned directly.
    fn sendrecv(&self, dest: usize, send_tag: i32, data: &[u8], src: usize, recv_tag: i32) -> Vec<u8> {
        let me = self.rank();
        if dest == me || src == me {
            assert!(
                dest == me && src == me && send_tag == recv_tag,
                "self sendrecv must be symmetric (dest == src == rank, matching tags)"
            );
            return data.to_vec();
        }
        // Sends are buffered on both transports (mailboxes / outbound
        // socket buffers with inbound draining), so send-then-recv
        // cannot deadlock even when both partners send first.
        self.send(dest, send_tag, data);
        self.recv(src, recv_tag)
    }

    /// Personalized all-to-all: `parts[d]` goes to rank `d`; returns the
    /// payloads received from every rank. Algorithm selected by
    /// [`AlltoallAlgorithm::Auto`]; use [`Comm::alltoall_with`] /
    /// [`Comm::alltoall_owned`] to choose explicitly.
    fn alltoall(&self, parts: &[Vec<u8>]) -> Vec<Vec<u8>> {
        self.alltoall_with(parts, AlltoallAlgorithm::Auto)
    }

    /// [`Comm::alltoall_owned`] over borrowed payloads.
    fn alltoall_with(&self, parts: &[Vec<u8>], algo: AlltoallAlgorithm) -> Vec<Vec<u8>> {
        self.alltoall_owned(parts.to_vec(), algo)
    }

    /// Personalized all-to-all taking ownership of the payloads: the
    /// rank→self part is *moved* into the result — zero bytes of
    /// self-traffic ever reach the transport (and none are even cloned),
    /// on every algorithm.
    fn alltoall_owned(&self, mut parts: Vec<Vec<u8>>, algo: AlltoallAlgorithm) -> Vec<Vec<u8>> {
        let n = self.size();
        assert_eq!(parts.len(), n, "alltoall payload count != comm size");
        let me = self.rank();
        if n == 1 {
            return parts;
        }
        let max_part = parts.iter().map(Vec::len).max().unwrap_or(0);
        match algo.resolve(n, max_part) {
            AlltoallAlgorithm::Auto => unreachable!("resolve() returns a concrete algorithm"),
            AlltoallAlgorithm::Linear => {
                let mut out = vec![Vec::new(); n];
                out[me] = std::mem::take(&mut parts[me]);
                // Ring schedule: round r sends to (me+r), receives from
                // (me-r); buffered sends make send-then-recv safe.
                for r in 1..n {
                    let send_to = (me + r) % n;
                    let recv_from = (me + n - r) % n;
                    self.send(send_to, T_COLL, &parts[send_to]);
                    parts[send_to] = Vec::new(); // free as we go
                    out[recv_from] = self.recv(recv_from, T_COLL);
                }
                out
            }
            AlltoallAlgorithm::Pairwise => {
                let mut out = vec![Vec::new(); n];
                out[me] = std::mem::take(&mut parts[me]);
                if n.is_power_of_two() {
                    // XOR partners: every round is one symmetric
                    // exchange, so each link is used bidirectionally at
                    // full rate and no rank waits on a chain of peers.
                    for r in 1..n {
                        let peer = me ^ r;
                        let sent = std::mem::take(&mut parts[peer]);
                        out[peer] = self.sendrecv(peer, T_COLL, &sent, peer, T_COLL);
                    }
                } else {
                    // Non-power-of-two: rotation schedule where round r
                    // pairs (me+r, me-r) — send and recv peers differ but
                    // every round still moves each rank's link once.
                    for r in 1..n {
                        let send_to = (me + r) % n;
                        let recv_from = (me + n - r) % n;
                        let sent = std::mem::take(&mut parts[send_to]);
                        self.send(send_to, T_COLL, &sent);
                        out[recv_from] = self.recv(recv_from, T_COLL);
                    }
                }
                out
            }
            AlltoallAlgorithm::Bruck => {
                // Bruck's algorithm: ceil(lg n) store-and-forward rounds.
                // 1. Local rotation: block i = the payload for relative
                //    destination i (distance upward from this rank).
                let mut blocks: Vec<Vec<u8>> =
                    (0..n).map(|i| std::mem::take(&mut parts[(me + i) % n])).collect();
                // 2. Round k ships every block whose relative index has
                //    bit 2^k set to rank (me + 2^k), bundled in one frame;
                //    received bundles land in the same slots. Block 0 (the
                //    self payload) has no bits set and never moves.
                let mut pow = 1usize;
                while pow < n {
                    let dst = (me + pow) % n;
                    let src = (me + n - pow) % n;
                    let idxs: Vec<usize> = (0..n).filter(|i| i & pow != 0).collect();
                    let bundle: Vec<Vec<u8>> =
                        idxs.iter().map(|&i| std::mem::take(&mut blocks[i])).collect();
                    let framed = frame(&bundle);
                    let got = self.sendrecv(dst, T_COLL, &framed, src, T_COLL);
                    for (&i, b) in idxs.iter().zip(unframe(&got, idxs.len())) {
                        blocks[i] = b;
                    }
                    pow <<= 1;
                }
                // 3. Inverse rotation: block i arrived from rank (me - i).
                let mut out = vec![Vec::new(); n];
                for (i, b) in blocks.into_iter().enumerate() {
                    out[(me + n - i) % n] = b;
                }
                out
            }
        }
    }

    /// All-reduce of one i64 (gather/bcast through rank 0).
    fn allreduce_i64(&self, op: ReduceOp, value: i64) -> i64 {
        let parts = self.allgather(&value.to_le_bytes());
        parts
            .iter()
            .map(|b| i64::from_le_bytes(b[..8].try_into().unwrap()))
            .reduce(|a, b| op.fold_i64(a, b))
            .unwrap()
    }

    /// All-reduce of one f64.
    fn allreduce_f64(&self, op: ReduceOp, value: f64) -> f64 {
        let parts = self.allgather(&value.to_le_bytes());
        parts
            .iter()
            .map(|b| f64::from_le_bytes(b[..8].try_into().unwrap()))
            .reduce(|a, b| op.fold_f64(a, b))
            .unwrap()
    }

    /// Inclusive prefix scan of one i64 (rank r receives fold of ranks
    /// `0..=r`). Used by the ordered shared-file-pointer collectives.
    fn scan_i64(&self, op: ReduceOp, value: i64) -> i64 {
        let parts = self.allgather(&value.to_le_bytes());
        parts[..=self.rank()]
            .iter()
            .map(|b| i64::from_le_bytes(b[..8].try_into().unwrap()))
            .reduce(|a, b| op.fold_i64(a, b))
            .unwrap()
    }

    /// Exclusive prefix sum of one i64 (rank r receives sum of ranks
    /// `0..r`; rank 0 receives `0`).
    fn exscan_sum_i64(&self, value: i64) -> i64 {
        self.scan_i64(ReduceOp::Sum, value) - value
    }

    /// The group of this communicator.
    fn group(&self) -> Group {
        Group::new((0..self.size()).collect())
    }

    /// This rank's first progress lane — see [`Comm::progress_lane_at`].
    fn progress_lane(&self) -> Option<ProgressLane> {
        self.progress_lane_at(0)
    }

    /// This rank's progress lane `lane` — a per-world background thread
    /// plus a `'static` endpoint in that lane's reserved tag band
    /// ([`progress`]) — used by the I/O layer to run nonblocking and
    /// split collective operations entirely off the calling thread.
    /// Independent collectives submitted to different lanes pipeline
    /// against each other. Transports that cannot hand out a `'static`
    /// endpoint (e.g. the borrowing [`SubComm`]) return `None` and
    /// nonblocking collectives fall back to caller-side exchange. The
    /// capability must be uniform across a world: every rank of a given
    /// communicator answers the same way.
    fn progress_lane_at(&self, lane: usize) -> Option<ProgressLane> {
        let _ = lane;
        None
    }
}

/// Frame a list of byte payloads into one buffer (u32 count, u64 lengths).
pub(crate) fn frame(parts: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + parts.iter().map(|p| p.len() + 8).sum::<usize>());
    out.extend_from_slice(&(parts.len() as u32).to_le_bytes());
    for p in parts {
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
    }
    for p in parts {
        out.extend_from_slice(p);
    }
    out
}

/// Inverse of [`frame`]; `expect` validates the part count.
pub(crate) fn unframe(buf: &[u8], expect: usize) -> Vec<Vec<u8>> {
    let count = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    assert_eq!(count, expect, "unframe: part count mismatch");
    let mut lens = Vec::with_capacity(count);
    let mut pos = 4;
    for _ in 0..count {
        lens.push(u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()) as usize);
        pos += 8;
    }
    let mut out = Vec::with_capacity(count);
    for len in lens {
        out.push(buf[pos..pos + len].to_vec());
        pos += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let parts = vec![vec![1u8, 2], vec![], vec![3u8; 100]];
        assert_eq!(unframe(&frame(&parts), 3), parts);
    }

    // The collectives themselves are exercised across transports in
    // threads.rs / process.rs tests and in rust/tests/comm_collectives.rs.
}
