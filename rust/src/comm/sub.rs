//! Sub-communicators: `MPI_Comm_split` over any transport.
//!
//! `split(color, key, context)` groups ranks by `color`, orders each group
//! by `(key, parent rank)`, and returns a [`SubComm`] that implements the
//! full [`Comm`] trait by delegating to the parent with translated ranks.
//! Disjoint groups can then drive independent collective file opens — the
//! pattern real applications use to give each component model its own
//! checkpoint file (the PIO design the paper surveys in §2.2.3 is built
//! around exactly this).
//!
//! MPI separates communicator traffic with hidden *contexts*; jpio
//! approximates that with a caller-supplied `context` id that salts the
//! tag space (tags must stay below [`MAX_USER_TAG`]). Two communicators
//! with different contexts never match each other's messages.

use super::{Comm, Group};

/// User tags must be below this bound so context salting cannot collide.
pub const MAX_USER_TAG: i32 = 1 << 20;

/// A communicator over a subset of a parent's ranks.
pub struct SubComm<'a> {
    parent: &'a dyn Comm,
    /// Parent ranks of the members, in sub-rank order.
    members: Vec<usize>,
    /// This process's rank within the sub-communicator.
    myrank: usize,
    /// Tag salt derived from the split context.
    salt: i32,
}

impl<'a> SubComm<'a> {
    /// Collective split: every rank of `parent` must call with its own
    /// `(color, key)`; ranks sharing a color form one sub-communicator,
    /// ordered by `(key, parent rank)`. `context` must be identical on
    /// all ranks and distinct from other live splits of the same parent
    /// (≤255 distinct contexts keep the salted tag space inside `i32`).
    pub fn split(parent: &'a dyn Comm, color: i32, key: i32, context: u8) -> SubComm<'a> {
        let mut payload = color.to_le_bytes().to_vec();
        payload.extend_from_slice(&key.to_le_bytes());
        let all = parent.allgather(&payload);
        let mut members: Vec<(i32, usize)> = Vec::new(); // (key, parent rank)
        for (rank, bytes) in all.iter().enumerate() {
            let c = i32::from_le_bytes(bytes[..4].try_into().unwrap());
            let k = i32::from_le_bytes(bytes[4..8].try_into().unwrap());
            if c == color {
                members.push((k, rank));
            }
        }
        members.sort_unstable();
        let members: Vec<usize> = members.into_iter().map(|(_, r)| r).collect();
        let myrank = members
            .iter()
            .position(|&r| r == parent.rank())
            .expect("calling rank must be in its own color group");
        SubComm {
            parent,
            members,
            myrank,
            salt: (context as i32 + 1) * MAX_USER_TAG,
        }
    }

    /// Parent rank of sub-rank `r`.
    pub fn parent_rank(&self, r: usize) -> usize {
        self.members[r]
    }

    fn salted(&self, tag: i32) -> i32 {
        if tag >= 0 {
            debug_assert!(tag < MAX_USER_TAG, "user tag {tag} exceeds MAX_USER_TAG");
            tag + self.salt
        } else {
            // Internal (negative) tags get their own salted band so the
            // sub-communicator's collectives cannot match the parent's.
            tag - self.salt
        }
    }
}

impl Comm for SubComm<'_> {
    fn rank(&self) -> usize {
        self.myrank
    }

    fn size(&self) -> usize {
        self.members.len()
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        self.parent.send(self.members[dest], self.salted(tag), data);
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        self.parent.recv(self.members[src], self.salted(tag))
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        self.parent.try_recv(self.members[src], self.salted(tag))
    }

    fn group(&self) -> Group {
        Group::new(self.members.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{threads, ReduceOp};

    #[test]
    fn split_by_parity_has_correct_shape() {
        threads::run(6, |c| {
            let color = (c.rank() % 2) as i32;
            let sub = SubComm::split(c, color, 0, 1);
            assert_eq!(sub.size(), 3);
            assert_eq!(sub.rank(), c.rank() / 2);
            assert_eq!(sub.parent_rank(sub.rank()), c.rank());
            // Collectives stay inside the group.
            let sum = sub.allreduce_i64(ReduceOp::Sum, c.rank() as i64);
            let want = if color == 0 { 0 + 2 + 4 } else { 1 + 3 + 5 };
            assert_eq!(sum, want);
        });
    }

    #[test]
    fn key_reorders_ranks() {
        threads::run(4, |c| {
            // Reverse order: highest parent rank becomes sub-rank 0.
            let sub = SubComm::split(c, 0, -(c.rank() as i32), 2);
            assert_eq!(sub.rank(), c.size() - 1 - c.rank());
            let mut data = if sub.rank() == 0 { vec![9u8] } else { vec![] };
            sub.bcast(0, &mut data);
            assert_eq!(data, vec![9u8]); // root is parent rank 3
        });
    }

    #[test]
    fn contexts_isolate_traffic() {
        threads::run(2, |c| {
            let a = SubComm::split(c, 0, 0, 10);
            let b = SubComm::split(c, 0, 0, 11);
            if c.rank() == 0 {
                a.send(1, 5, b"via-a");
                b.send(1, 5, b"via-b");
            } else {
                // Receive in the *opposite* order: context salting means
                // b's message cannot be stolen by a's receive.
                assert_eq!(b.recv(0, 5), b"via-b");
                assert_eq!(a.recv(0, 5), b"via-a");
            }
        });
    }

    #[test]
    fn disjoint_groups_open_independent_files() {
        use crate::io::{amode, File, Info};
        use crate::comm::Datatype;
        let base = format!("/tmp/jpio-subcomm-{}", std::process::id());
        let b2 = base.clone();
        threads::run(4, move |c| {
            let color = (c.rank() / 2) as i32;
            let sub = SubComm::split(c, color, 0, 3);
            let path = format!("{b2}-{color}.dat");
            let f = File::open(&sub, &path, amode::RDWR | amode::CREATE, Info::null())
                .unwrap();
            let mine = vec![(color * 10 + sub.rank() as i32); 16];
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            f.write_at_all((sub.rank() * 16) as i64, mine.as_slice(), 0, 16, &Datatype::INT)
                .unwrap();
            sub.barrier();
            f.close().unwrap();
        });
        for color in 0..2 {
            let raw = std::fs::read(format!("{base}-{color}.dat")).unwrap();
            let ints: Vec<i32> = raw
                .chunks_exact(4)
                .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            assert_eq!(ints.len(), 32);
            assert!(ints[..16].iter().all(|&v| v == color * 10));
            assert!(ints[16..].iter().all(|&v| v == color * 10 + 1));
            let _ = std::fs::remove_file(format!("{base}-{color}.dat"));
            let _ = std::fs::remove_file(format!("{base}-{color}.dat.jpio-sfp"));
        }
    }
}
