//! Process groups (`mpj.Group`): the set of ranks that collectively opened
//! a file (`MPI_FILE_GET_GROUP`, §7.2.2.7).

/// An ordered set of ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Group {
    ranks: Vec<usize>,
}

impl Group {
    /// Build a group from an explicit rank list.
    pub fn new(ranks: Vec<usize>) -> Self {
        Group { ranks }
    }

    /// Number of members.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// The global ranks of the members, in group order.
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// Translate a group-local index to a global rank.
    pub fn translate(&self, local: usize) -> Option<usize> {
        self.ranks.get(local).copied()
    }

    /// Position of a global rank inside the group, if present.
    pub fn rank_of(&self, global: usize) -> Option<usize> {
        self.ranks.iter().position(|&r| r == global)
    }

    /// Set intersection, preserving this group's order.
    pub fn intersect(&self, other: &Group) -> Group {
        Group::new(
            self.ranks
                .iter()
                .copied()
                .filter(|r| other.ranks.contains(r))
                .collect(),
        )
    }

    /// Set union: members of `self` then members of `other` not in `self`.
    pub fn union(&self, other: &Group) -> Group {
        let mut ranks = self.ranks.clone();
        for &r in &other.ranks {
            if !ranks.contains(&r) {
                ranks.push(r);
            }
        }
        Group::new(ranks)
    }

    /// Set difference: members of `self` not in `other`.
    pub fn difference(&self, other: &Group) -> Group {
        Group::new(
            self.ranks
                .iter()
                .copied()
                .filter(|r| !other.ranks.contains(r))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn translate_and_rank_of() {
        let g = Group::new(vec![3, 1, 4]);
        assert_eq!(g.size(), 3);
        assert_eq!(g.translate(2), Some(4));
        assert_eq!(g.translate(3), None);
        assert_eq!(g.rank_of(1), Some(1));
        assert_eq!(g.rank_of(9), None);
    }

    #[test]
    fn set_operations() {
        let a = Group::new(vec![0, 1, 2, 3]);
        let b = Group::new(vec![2, 3, 4]);
        assert_eq!(a.intersect(&b).ranks(), &[2, 3]);
        assert_eq!(a.union(&b).ranks(), &[0, 1, 2, 3, 4]);
        assert_eq!(a.difference(&b).ranks(), &[0, 1]);
    }
}
