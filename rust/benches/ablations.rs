//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **per-item vs bulk** — the §2.3.1 DataStream result: element-at-a-
//!    time I/O vs one syscall per run.
//! 2. **two-phase collective I/O on/off** — interleaved strided writes
//!    with and without collective buffering (ROMIO's headline win).
//! 3. **data sieving stage size** — strided reads with a tiny vs large
//!    staging buffer.
//! 4. **atomic mode cost** — the §7.2.6.1 locking overhead per write.
//! 5. **PJRT pack kernel vs Rust scalar pack** — L1 ablation (skipped if
//!    artifacts are absent).
//! 6. **striped storage** — stripe-count × stripe-unit sweep (aggregate
//!    bandwidth scaling past one server's ingest rate), stripe-aligned
//!    vs unaligned collective file domains (the Thakur alignment win),
//!    and redundancy modes (6c: none vs replica:2 vs parity write
//!    overhead — the RAID-5 small-write penalty — plus degraded-read
//!    bandwidth with one server killed).
//! 7. **nonblocking collective overlap** — `iwrite_at_all`/`iread_at_all`
//!    hiding the whole collective (exchange + I/O phases, on the
//!    per-world progress threads) behind computation vs the blocking
//!    routines; asserts wall-clock < blocking I/O + compute when the
//!    modelled I/O dominates noise.
//! 8. **IoPlan pipeline parity** — the same strided access through the
//!    full File → IoPlan → IoScheduler pipeline vs calling the strategy
//!    on pre-flattened runs (the compiler must cost nothing measurable).
//! 9. **stats instrumentation cost** — the 4 KiB independent-write hot
//!    path with `jpio_stats` unset (counters only) vs phase timers on vs
//!    timers + JSONL tracing; proves the hint-off path records no phase
//!    samples (timers fully skipped) and validates every emitted trace
//!    line against the `TraceEvent` schema.
//! 10. **scale-out exchange** — forked-rank sweep (2→64) of the alltoall
//!    schedules: a transport tap proves linear/pairwise move `n-1`
//!    messages per rank (Θ(n²) total) while Bruck moves `⌈lg n⌉`
//!    (Θ(n lg n) — sub-quadratic), with wall-clock per exchange printed
//!    alongside; plus the zero-copy collective-write guard — the
//!    `staging_copy_bytes` counter must be 0 on plan-executing (striped)
//!    backends and exactly the payload on the staged fallback.
//! 11. **page cache + write-behind** — 4 KiB strided writes through the
//!    `jpio_cache` write-behind layer vs one bulk write vs the same
//!    small writes uncached, on the modelled NFS backend where every
//!    small write pays an RPC; asserts write-behind reaches ≥ 50% of
//!    bulk bandwidth, and that `jpio_cache = disable` leaves the file
//!    byte-identical with every cache counter at zero.
//! 12. **dataset layer vs hand-rolled views** — the structured dataset
//!    subarray sweep (`put_vara` over a 2×2 block decomposition) vs the
//!    same access hand-rolled with `darray_block` views and
//!    `write_at_all`; asserts dataset bandwidth within 1.5× of raw
//!    views and that repeated same-shape `put_vara` climbs the
//!    PlanCache hit counter (the cached per-shape view keys the plan).
//! 13. **elastic rebuild** — kill → blank-replace → rebuild →
//!    bandwidth-restored curve on striped parity: read bandwidth before
//!    the kill, degraded (XOR-reconstructing) under it, and after the
//!    background-rebuild engine re-materializes the replacement server;
//!    asserts post-rebuild read bandwidth ≥ 90% of pre-kill and *zero*
//!    degraded-read reconstructions after the rebuild (BackendCounters).
//!
//! `JPIO_SMOKE=1` runs everything at 1/16 size with one repetition — the
//! CI gate that keeps this file compiled and executed on every PR.

#[path = "common.rs"]
mod common;

use jpio::bench::{bench, FigureReport};
use jpio::comm::{threads, Comm, Datatype};
use jpio::io::{amode, File, Info};

fn per_item_vs_bulk() {
    println!("\n--- ablation 1: per-item vs bulk (the paper's §2.3.1 result) ---");
    let path = format!("/tmp/jpio-abl1-{}.dat", std::process::id());
    let bytes = common::sz(4 << 20); // per-item is brutally slow; keep it small
    let mut results = Vec::new();
    for style in ["per_item", "bulk", "view_buffer"] {
        let st = common::thread_sweep_case(
            std::sync::Arc::new(jpio::storage::local::LocalBackend::instant()),
            &path,
            bytes,
            1,
            style,
            true,
        );
        println!("  write {style:<12} {:10.1} MB/s", st.mbs());
        results.push((style, st.mbs()));
    }
    let per_item = results[0].1;
    let bulk = results[1].1;
    println!(
        "  bulk / per-item speedup: {:.0}x (paper: DataStream-style I/O is \
         'extremely inefficient')",
        bulk / per_item
    );
    common::cleanup(&path);
}

fn two_phase_on_off() {
    println!("\n--- ablation 2: two-phase collective buffering on/off (NFS) ---");
    // The two-phase win needs per-operation cost: on the Barq NFS model
    // every WRITE RPC pays latency, so thousands of 256 B strided writes
    // lose badly to a few aggregated megabyte transfers. (On the instant
    // local backend the two paths are within noise — also reported.)
    let path = format!("/tmp/jpio-abl2-{}.dat", std::process::id());
    let ranks = 4;
    let k = common::sz(16 << 10); // etypes (ints) per rank
    let chunk = 64; // ints per interleaved cell → 256 B pieces
    for (label, cb) in [("two-phase ON ", "true"), ("two-phase OFF", "false")] {
        let stats = bench(label, 1, common::reps(), ranks * k * 4, || {
            threads::run(ranks, |c| {
                let info = Info::from([
                    ("romio_cb_read", cb),
                    ("cb_buffer_size", "16777216"),
                ]);
                let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                    std::sync::Arc::new(jpio::storage::nfs::NfsBackend::barq());
                let f = File::open_with_backend(
                    c,
                    &path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend,
                )
                .unwrap();
                let n = c.size();
                let r = c.rank();
                // Interleaved cells of `chunk` ints: the two-phase sweet spot.
                let cell = Datatype::vector(1, chunk, chunk as i64, &Datatype::INT).unwrap();
                let ft = Datatype::resized(&cell, 0, (n * chunk * 4) as i64).unwrap();
                f.set_view((r * chunk * 4) as i64, &Datatype::INT, &ft, "native", &Info::null())
                    .unwrap();
                let mine = vec![r as i32; k];
                f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
                f.close().unwrap();
            });
        });
        println!("  {label}: {:10.1} MB/s", stats.mbs());
    }
    common::cleanup(&path);
}

fn sieving_stage_size() {
    println!("\n--- ablation 3: data-sieving stage size (strided reads) ---");
    let path = format!("/tmp/jpio-abl3-{}.dat", std::process::id());
    {
        let b: std::sync::Arc<dyn jpio::storage::Backend> =
            std::sync::Arc::new(jpio::storage::local::LocalBackend::instant());
        common::prewrite(&b, &path, common::sz(32 << 20));
    }
    let k = common::sz(32 << 10);
    let chunk = 16; // 64 B cells with 192 B holes
    for stage in ["4096", "262144", "8388608"] {
        let stats = bench(stage, 1, common::reps(), k * 4, || {
            threads::run(1, |c| {
                let info = Info::from([("ind_rd_buffer_size", stage)]);
                let f = File::open(c, &path, amode::RDONLY, info).unwrap();
                let cell = Datatype::vector(1, chunk, chunk as i64, &Datatype::INT).unwrap();
                let ft = Datatype::resized(&cell, 0, (4 * chunk * 4) as i64).unwrap();
                f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
                let mut buf = vec![0i32; k];
                f.read_at(0, buf.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
                f.close().unwrap();
            });
        });
        println!("  stage {stage:>8} B: {:10.1} MB/s (payload rate)", stats.mbs());
    }
    common::cleanup(&path);
}

fn write_sieving_on_off() {
    println!("\n--- ablation 3b: write data-sieving (RMW) vs per-run writes (NFS) ---");
    // Independent (noncollective) strided writes: per-run writes pay one
    // WRITE RPC per 256 B piece; the sieving strategy batches the span
    // into one read-modify-write round trip.
    let path = format!("/tmp/jpio-abl3b-{}.dat", std::process::id());
    let k = common::sz(8 << 10); // ints
    let chunk = 64;
    for style in ["view_buffer", "data_sieving"] {
        let stats = bench(style, 1, common::reps(), k * 4, || {
            threads::run(1, |c| {
                let info = Info::from([("access_style", style)]);
                let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                    std::sync::Arc::new(jpio::storage::nfs::NfsBackend::barq());
                let f = File::open_with_backend(
                    c,
                    &path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend,
                )
                .unwrap();
                let cell = Datatype::vector(1, chunk, chunk as i64, &Datatype::INT).unwrap();
                let ft = Datatype::resized(&cell, 0, (4 * chunk * 4) as i64).unwrap();
                f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
                let mine = vec![7i32; k];
                f.write_at(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
                f.close().unwrap();
            });
        });
        println!("  {style:<14}: {:10.1} MB/s (payload rate)", stats.mbs());
    }
    common::cleanup(&path);
}

fn atomic_mode_cost() {
    println!("\n--- ablation 4: atomic-mode locking cost ---");
    let path = format!("/tmp/jpio-abl4-{}.dat", std::process::id());
    let ops = common::sz(4096);
    for atomic in [false, true] {
        let stats = bench(
            if atomic { "atomic" } else { "nonatomic" },
            1,
            common::reps(),
            ops * 1024,
            || {
                threads::run(2, |c| {
                    let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null())
                        .unwrap();
                    f.set_atomicity(atomic).unwrap();
                    let buf = vec![c.rank() as u8; 1024];
                    for i in 0..ops / 2 {
                        let off = ((i * 2 + c.rank()) * 1024) as i64;
                        f.write_at(off, buf.as_slice(), 0, 1024, &Datatype::BYTE).unwrap();
                    }
                    f.close().unwrap();
                });
            },
        );
        println!(
            "  {}: {:10.1} MB/s",
            if atomic { "atomic   " } else { "nonatomic" },
            stats.mbs()
        );
    }
    common::cleanup(&path);
}

fn pjrt_pack_vs_rust() {
    println!("\n--- ablation 5: Pallas pack kernel vs Rust scalar pack ---");
    let rt = match jpio::runtime::Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(_) => {
            println!("  SKIPPED: artifacts missing (run `make artifacts`)");
            return;
        }
    };
    let halo = 258;
    let interior = 256;
    let x = jpio::runtime::TensorF32::new(
        (0..halo * halo).map(|i| i as f32).collect(),
        vec![halo, halo],
    );
    let bytes = interior * interior * 4;
    let pjrt = bench("pjrt", 2, 10, bytes, || {
        let _ = rt.exec_f32("pack", &[x.clone()]).unwrap();
    });
    let rust = bench("rust", 2, 10, bytes, || {
        let mut out = vec![0f32; interior * interior];
        for r in 0..interior {
            let src = (r + 1) * halo + 1;
            out[r * interior..(r + 1) * interior]
                .copy_from_slice(&x.data[src..src + interior]);
        }
        std::hint::black_box(&out);
    });
    println!(
        "  pjrt pack (interpret-lowered):  {:10.1} MB/s\n  rust scalar pack: {:10.1} MB/s\n  \
         note: interpret=True CPU numbers — structure, not TPU wallclock (DESIGN.md §Perf)",
        pjrt.mbs(),
        rust.mbs()
    );
}

fn cleanup_striped(path: &str, servers: usize) {
    common::cleanup(path);
    // Delete through the backend so the stripe-object naming stays in
    // one place (the unit is irrelevant for deletion).
    let b = jpio::storage::striped::StripedBackend::local(servers, 1);
    let _ = jpio::storage::Backend::delete(&b, path);
}

fn striped_storage_scaling() {
    println!("\n--- ablation 6a: striped NFS — aggregate write bandwidth vs stripe count ---");
    // Each of 4 rank-threads streams its contiguous partition. Round-robin
    // striping spreads every partition over all servers, so the per-server
    // ingest serialization (one NFS server ≈ 275 MB/s, Fig 4-5) stops
    // being a single global bottleneck and aggregate bandwidth scales
    // with the stripe count.
    let total = common::sz(16 << 20);
    for servers in [1usize, 2, 4] {
        for unit in [64usize << 10, 1 << 20] {
            let path = format!("/tmp/jpio-abl6-{}-{servers}-{unit}.dat", std::process::id());
            let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                std::sync::Arc::new(jpio::storage::striped::StripedBackend::nfs(
                    servers,
                    unit as u64,
                    jpio::storage::nfs::NfsConfig::rcms(),
                ));
            let st = common::thread_sweep_case(backend, &path, total, 4, "view_buffer", true);
            println!(
                "  {servers} server(s), unit {unit:>8} B: {:8.1} MB/s aggregate write",
                st.mbs()
            );
            cleanup_striped(&path, servers);
        }
    }
}

fn striped_alignment_on_off() {
    println!("\n--- ablation 6b: collective write — stripe-aligned vs unaligned file domains ---");
    // 4 ranks, 4 NFS stripe servers, cb_nodes = 4. Aligned (stripe-cyclic)
    // domains hand each aggregator exactly one server, so the four ingest
    // sections run in parallel; unaligned contiguous domains make every
    // aggregator write through all four servers and contend for every
    // ingest lock (Thakur/Gropp/Lusk's file-domain alignment).
    let servers = 4usize;
    let unit = 256usize << 10;
    let ranks = 4usize;
    let per_rank = common::sz(4usize << 20);
    let mut mbs = Vec::new();
    for (label, align) in [("aligned  ", "true"), ("unaligned", "false")] {
        let path = format!("/tmp/jpio-abl6b-{}-{align}.dat", std::process::id());
        let stats = bench(label, 1, common::reps(), ranks * per_rank, || {
            threads::run(ranks, |c| {
                let info = Info::from([
                    ("jpio_cb_stripe_align", align),
                    ("cb_nodes", "4"),
                ]);
                let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                    std::sync::Arc::new(jpio::storage::striped::StripedBackend::nfs(
                        servers,
                        unit as u64,
                        jpio::storage::nfs::NfsConfig::rcms(),
                    ));
                let f = File::open_with_backend(
                    c,
                    &path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend,
                )
                .unwrap();
                let r = c.rank();
                let mine = vec![r as u8; per_rank];
                f.write_at_all(
                    (r * per_rank) as i64,
                    mine.as_slice(),
                    0,
                    per_rank,
                    &Datatype::BYTE,
                )
                .unwrap();
                f.close().unwrap();
            });
        });
        println!("  {label}: {:8.1} MB/s aggregate", stats.mbs());
        mbs.push(stats.mbs());
        cleanup_striped(&path, servers);
    }
    println!(
        "  alignment speedup: {:.2}x (aggregators stop contending for each other's servers)",
        mbs[0] / mbs[1]
    );
}

fn striped_redundancy_modes() {
    println!("\n--- ablation 6c: stripe redundancy — write overhead and degraded reads ---");
    // 4 local children, 64 KiB units. Replica writes pay k× the bytes;
    // parity writes pay the RAID-5 read-modify-write (row reads + the
    // stripe-consistency lock). Degraded reads (one server killed via
    // faults.rs) pay reconstruction: replica falls over to a copy,
    // parity XORs the surviving three servers. This is also the CI
    // smoke gate's degraded-read configuration (JPIO_SMOKE=1).
    use jpio::io::ErrorClass;
    use jpio::storage::faults::{FaultBackend, FaultPlan};
    use jpio::storage::layout::Redundancy;
    use jpio::storage::local::LocalBackend;
    use jpio::storage::striped::StripedBackend;
    use jpio::storage::{Backend, OpenOptions, StorageFile};
    let total = common::sz(16 << 20);
    let unit = 64u64 << 10;
    for (label, redundancy) in [
        ("none     ", Redundancy::None),
        ("replica:2", Redundancy::Replica(2)),
        ("parity   ", Redundancy::Parity),
    ] {
        let plan = FaultPlan::new(vec![]);
        let children: Vec<std::sync::Arc<dyn Backend>> = (0..4)
            .map(|i| {
                if i == 1 {
                    std::sync::Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                        as std::sync::Arc<dyn Backend>
                } else {
                    std::sync::Arc::new(LocalBackend::instant()) as std::sync::Arc<dyn Backend>
                }
            })
            .collect();
        let backend =
            StripedBackend::with_redundancy(children, unit, redundancy).unwrap();
        let path = format!("/tmp/jpio-abl6c-{}-{}.dat", std::process::id(), label.trim());
        let payload = vec![0x5Au8; total];
        let f = backend.open(&path, OpenOptions::rw_create()).unwrap();
        let wr = bench(format!("write/{label}"), 1, common::reps(), total, || {
            f.write_at(0, &payload).unwrap();
        });
        let mut buf = vec![0u8; total];
        let healthy = bench(format!("read/{label}"), 1, common::reps(), total, || {
            assert_eq!(f.read_at(0, &mut buf).unwrap(), total);
        });
        print!(
            "  {label}: write {:8.1} MB/s   healthy read {:8.1} MB/s",
            wr.mbs(),
            healthy.mbs()
        );
        if redundancy == Redundancy::None {
            println!("   degraded read: n/a (a lost server fails the file)");
        } else {
            // Kill server 1 and read through reconstruction.
            plan.inject_kill(ErrorClass::Io);
            let degraded = bench(format!("degraded/{label}"), 1, common::reps(), total, || {
                assert_eq!(f.read_at(0, &mut buf).unwrap(), total);
            });
            assert_eq!(buf, payload, "degraded read corrupted data ({label})");
            let advisories = f.take_advisories();
            assert!(
                advisories.iter().all(|a| a.class == ErrorClass::Degraded)
                    && !advisories.is_empty(),
                "degraded read must surface JPIO_ERR_DEGRADED advisories"
            );
            println!("   degraded read {:8.1} MB/s", degraded.mbs());
        }
        drop(f);
        let _ = jpio::storage::Backend::delete(&backend, &path);
    }
}

fn nonblocking_collective_overlap() {
    println!("\n--- ablation 7: i{{write,read}}_at_all overlap vs blocking (NFS) ---");
    // Each rank moves its block collectively, then "computes" a fixed
    // spin. With the per-world progress engine, the nonblocking
    // collective's exchange *and* I/O phases run on the progress
    // threads, so the modelled NFS time hides behind the compute; the
    // blocking path pays them back-to-back. The acceptance inequality —
    // overlapped wall-clock < blocking-I/O + compute — is asserted
    // whenever the modelled I/O is large enough to dominate scheduler
    // noise (full runs; the smoke gate still executes every path).
    let path = format!("/tmp/jpio-abl7-{}.dat", std::process::id());
    let ranks = 4usize;
    let per_rank = common::sz(2 << 20);
    // Sized so the full-run spin is comparable to the modelled NFS time
    // (tens of ms) — overlap shows up as wall-clock, not just MB/s.
    let iters = common::sz(32_000_000) as u64;
    let compute = move || {
        let mut acc = 0u64;
        for i in 0..iters {
            acc = acc.wrapping_mul(31).wrapping_add(i);
        }
        std::hint::black_box(acc);
    };
    let world = |with_compute: bool, mode: u8| {
        threads::run(ranks, |c| {
            let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                std::sync::Arc::new(jpio::storage::nfs::NfsBackend::barq());
            let f = File::open_with_backend(
                c,
                &path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend,
            )
            .unwrap();
            let r = c.rank();
            let off = (r * per_rank) as i64;
            match mode {
                0 => {
                    // Blocking collective write.
                    let mine = vec![r as u8; per_rank];
                    f.write_at_all(off, mine.as_slice(), 0, per_rank, &Datatype::BYTE).unwrap();
                    if with_compute {
                        compute();
                    }
                }
                1 => {
                    // Nonblocking collective write, compute overlapped.
                    let mine = vec![r as u8; per_rank];
                    let req = f
                        .iwrite_at_all(off, mine.as_slice(), 0, per_rank, &Datatype::BYTE)
                        .unwrap();
                    compute();
                    req.wait().unwrap();
                }
                _ => {
                    // Nonblocking collective read, compute overlapped.
                    let req = f
                        .iread_at_all(off, vec![0u8; per_rank], 0, per_rank, &Datatype::BYTE)
                        .unwrap();
                    compute();
                    let (st, back) = req.wait().unwrap();
                    assert_eq!(st.bytes, per_rank);
                    assert!(back.iter().all(|&b| b == r as u8), "overlap corrupted data");
                }
            }
            f.close().unwrap();
        });
    };
    // Warm-up creates the file and spawns the progress threads.
    world(false, 0);
    let t = |f: &dyn Fn()| {
        let start = std::time::Instant::now();
        f();
        start.elapsed()
    };
    let io_only = t(&|| world(false, 0));
    let compute_only = t(&|| {
        threads::run(ranks, |_| compute());
    });
    let blocking = t(&|| world(true, 0));
    let iwrite = t(&|| world(true, 1));
    let iread = t(&|| world(true, 2));
    let total = (ranks * per_rank) as f64 / (1 << 20) as f64;
    println!("  write_at_all (I/O only):        {io_only:>9.2?}");
    println!("  compute only:                   {compute_only:>9.2?}");
    println!("  write_at_all  + compute:        {blocking:>9.2?}  ({:.1} MB/s eff.)",
        total / blocking.as_secs_f64());
    println!("  iwrite_at_all + compute:        {iwrite:>9.2?}  ({:.1} MB/s eff.)",
        total / iwrite.as_secs_f64());
    println!("  iread_at_all  + compute:        {iread:>9.2?}  (data verified)");
    let hidden = blocking.saturating_sub(iwrite);
    println!(
        "  overlap hides {hidden:.2?} ({:.0}% of blocking wall-clock)",
        100.0 * hidden.as_secs_f64() / blocking.as_secs_f64().max(1e-9)
    );
    if io_only > std::time::Duration::from_millis(20)
        && compute_only > std::time::Duration::from_millis(5)
    {
        let budget = io_only + compute_only;
        assert!(
            iwrite < budget,
            "nonblocking collective failed to overlap: {iwrite:?} >= I/O {io_only:?} + \
             compute {compute_only:?}"
        );
    }
    common::cleanup(&path);
}

fn plan_pipeline_parity() {
    println!("\n--- ablation 8: IoPlan pipeline vs direct strategy dispatch ---");
    // The same strided write issued (a) through the full File → IoPlan →
    // IoScheduler pipeline and (b) by calling the strategy on runs
    // flattened once up front. The unified compiler must be free:
    // coalesced plans no slower than hand-rolled dispatch.
    use jpio::io::{DataRep, FileView};
    use jpio::storage::{Backend, OpenOptions};
    use jpio::strategy::{AccessStrategy, ViewBufStrategy};
    let path = format!("/tmp/jpio-abl8-{}.dat", std::process::id());
    let k = common::sz(256 << 10); // ints
    let chunk = 16usize; // 64 B cells with 64 B holes
    let mk_ft = || {
        let cell = Datatype::vector(1, chunk, chunk as i64, &Datatype::INT).unwrap();
        Datatype::resized(&cell, 0, (2 * chunk * 4) as i64).unwrap()
    };
    let payload = vec![7i32; k];
    // Open + set_view are hoisted out of the timed region on both sides:
    // the two measurements differ only in who flattens and dispatches.
    let mut pipeline = threads::run(1, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &mk_ft(), "native", &Info::null()).unwrap();
        let st = bench("pipeline", 1, common::reps(), k * 4, || {
            f.write_at(0, payload.as_slice(), 0, k, &Datatype::INT).unwrap();
        });
        f.close().unwrap();
        st
    });
    let pipeline = pipeline.pop().expect("one rank");
    // Direct: flatten once, dispatch the same runs straight at the
    // strategy (what each access family hand-rolled before the refactor).
    let view = FileView::new(0, Datatype::INT, mk_ft(), DataRep::Native).unwrap();
    let runs = view.runs(0, k * 4).unwrap();
    let bytes: Vec<u8> = payload.iter().flat_map(|v| v.to_le_bytes()).collect();
    let backend = jpio::storage::local::LocalBackend::instant();
    let file = backend.open(&path, OpenOptions::rw_create()).unwrap();
    let strat = ViewBufStrategy::default();
    let direct = bench("direct", 1, common::reps(), k * 4, || {
        strat.write(file.as_ref(), &runs, &bytes).unwrap();
    });
    println!(
        "  File→IoPlan→IoScheduler: {:10.1} MB/s\n  pre-flattened direct:    {:10.1} MB/s\n  \
         pipeline/direct ratio: {:.2}x (≥ ~1 means the compiler is free)",
        pipeline.mbs(),
        direct.mbs(),
        pipeline.mbs() / direct.mbs()
    );
    common::cleanup(&path);
}

fn stats_instrumentation() {
    println!("\n--- ablation 9: Darshan-style stats instrumentation cost ---");
    use jpio::io::{StatsReport, TraceEvent};
    let path = format!("/tmp/jpio-abl9-{}.dat", std::process::id());
    let trace = format!("/tmp/jpio-abl9-{}.jsonl", std::process::id());
    let k = 1024usize; // ints → the 4 KiB independent-write hot path
    let writes = common::sz(4096); // ops per repetition
    let payload = vec![3i32; k];

    // One timed case: `writes` independent 4 KiB writes through a handle
    // opened with `info`. Returns (MB/s, the handle's local report).
    let case = |label: &str, info: Info| -> (f64, StatsReport) {
        let payload = payload.clone();
        let path = path.clone();
        let mut out = threads::run(1, move |c| {
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info.clone()).unwrap();
            let st = bench(label, 1, common::reps(), writes * k * 4, || {
                for i in 0..writes {
                    f.write_at((i * k) as i64, payload.as_slice(), 0, k, &Datatype::INT)
                        .unwrap();
                }
            });
            let report = f.stats();
            f.close().unwrap();
            (st.mbs(), report)
        });
        out.pop().expect("one rank")
    };

    let (off_mbs, off_report) = case("stats off  ", Info::null());
    let (on_mbs, on_report) = case("stats on   ", Info::from([("jpio_stats", "true")]));
    let (trace_mbs, _) = case(
        "stats+trace",
        Info::from([("jpio_stats", "true"), ("jpio_stats_trace", trace.as_str())]),
    );
    println!("  hint off (counters only): {off_mbs:10.1} MB/s");
    println!("  phase timers on:          {on_mbs:10.1} MB/s");
    println!("  timers + JSONL trace:     {trace_mbs:10.1} MB/s");
    println!(
        "  off/on ratio: {:.2}x (≥ ~1 means the hint-off hot path pays nothing)",
        off_mbs / on_mbs
    );

    // Functional proof of "near-zero cost when off": the hint-off run
    // counted every op but recorded not a single phase sample — the
    // timers never read the clock.
    assert_eq!(off_report.counter("write_ops").sum as usize, writes * (1 + common::reps()));
    for (name, p) in off_report.phases() {
        assert_eq!(p.samples.sum, 0, "hint off: phase {name} must record no samples");
    }
    assert!(
        on_report.phase("storage").samples.sum >= writes as u64,
        "hint on: every write records a storage span"
    );
    // Guarded timing assertion (ablation-7 pattern): only when the runs
    // are far enough above timer noise, the counters-only path must not
    // run measurably slower than the fully timed path.
    if off_mbs > 0.0 && on_mbs > 0.0 && writes >= 1024 {
        assert!(
            off_mbs >= 0.5 * on_mbs,
            "hint-off hot path slower than timers-on beyond noise: {off_mbs:.1} vs {on_mbs:.1} MB/s"
        );
    }

    // Schema validation of the traced run: every emitted line must parse
    // with the reference decoder and round-trip byte-identically.
    let stream = std::fs::read_to_string(format!("{trace}.0")).expect("per-rank trace file");
    let mut ops = 0usize;
    for line in stream.lines() {
        let ev = TraceEvent::parse(line)
            .unwrap_or_else(|| panic!("trace line failed schema validation: {line}"));
        assert_eq!(ev.to_json(), line, "canonical encode must round-trip");
        if ev.kind == "op" {
            assert_eq!(ev.name, "write_at");
            assert_eq!(ev.bytes, (k * 4) as u64);
            ops += 1;
        }
    }
    assert_eq!(ops, writes * (1 + common::reps()), "one op event per write");
    println!("  trace: {ops} op events validated against the TraceEvent schema");
    let _ = std::fs::remove_file(format!("{trace}.0"));
    common::cleanup(&path);
}

/// Transport tap for ablation 10: the alltoall schedules run on the
/// trait's `send`/`recv`/`sendrecv` defaults, so counting here measures
/// each algorithm's true per-rank transport footprint.
struct SendTap<'a> {
    inner: &'a dyn Comm,
    msgs: std::sync::atomic::AtomicU64,
    bytes: std::sync::atomic::AtomicU64,
}

impl<'a> SendTap<'a> {
    fn new(inner: &'a dyn Comm) -> SendTap<'a> {
        SendTap {
            inner,
            msgs: std::sync::atomic::AtomicU64::new(0),
            bytes: std::sync::atomic::AtomicU64::new(0),
        }
    }
}

impl Comm for SendTap<'_> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        use std::sync::atomic::Ordering;
        self.msgs.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.inner.send(dest, tag, data)
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        self.inner.recv(src, tag)
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        self.inner.try_recv(src, tag)
    }
}

fn scaleout_exchange_and_zero_copy() {
    println!("\n--- ablation 10: scale-out alltoall (forked-rank sweep) + zero-copy write path ---");
    use jpio::comm::{process, AlltoallAlgorithm};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    // Part A — the exchange sweep. Forked ranks (real address spaces on
    // the socket mesh) run each schedule at each world size; rank 0
    // reports the per-exchange wall-clock plus the tap's message and
    // byte counts. The counts are deterministic, so the sub-quadratic
    // claim is asserted structurally: linear and pairwise pay n-1
    // messages per rank, Bruck pays ceil(lg n) bundled frames.
    let sizes: &[usize] = if common::smoke() { &[2, 4, 8] } else { &[2, 4, 8, 16, 32, 64] };
    let per_dest = common::sz(16 << 10);
    let iters = common::reps();
    let algos = [
        ("linear", AlltoallAlgorithm::Linear),
        ("pairwise", AlltoallAlgorithm::Pairwise),
        ("bruck", AlltoallAlgorithm::Bruck),
    ];
    println!("  per-destination payload {per_dest} B, {iters} timed exchanges per cell");
    println!(
        "  {:>6} {:>10} {:>14} {:>10} {:>16}",
        "ranks", "algorithm", "per-exchange", "msgs/rank", "wire B/rank"
    );
    for &n in sizes {
        for &(name, algo) in &algos {
            let (secs, msgs, bytes) = process::run_local(n, |c| {
                let tap = SendTap::new(c);
                let me = tap.rank();
                // Warm-up doubles as a correctness pass: every payload
                // byte encodes its (src, dst) pair.
                let parts: Vec<Vec<u8>> =
                    (0..n).map(|d| vec![(me * 31 + d) as u8; per_dest]).collect();
                let inbound = tap.alltoall_with(&parts, algo);
                for (s, got) in inbound.iter().enumerate() {
                    assert_eq!(got.len(), per_dest, "rank {me} from {s} under {name}");
                    assert!(got.iter().all(|&v| v == (s * 31 + me) as u8));
                }
                tap.msgs.store(0, Ordering::Relaxed);
                tap.bytes.store(0, Ordering::Relaxed);
                c.barrier(); // uncounted: keep the tap to alltoall traffic
                let start = std::time::Instant::now();
                for _ in 0..iters {
                    let parts: Vec<Vec<u8>> =
                        (0..n).map(|d| vec![(me + d) as u8; per_dest]).collect();
                    std::hint::black_box(tap.alltoall_with(&parts, algo));
                }
                c.barrier();
                (
                    start.elapsed().as_secs_f64() / iters as f64,
                    tap.msgs.load(Ordering::Relaxed) / iters as u64,
                    tap.bytes.load(Ordering::Relaxed) / iters as u64,
                )
            });
            println!(
                "  {n:>6} {name:>10} {:>11.3} ms {msgs:>10} {bytes:>16}",
                secs * 1e3
            );
            // Sweep sizes are powers of two, so the pairwise XOR
            // schedule and the exact Bruck round count both apply.
            let lg = (usize::BITS - (n - 1).leading_zeros()) as u64;
            match algo {
                AlltoallAlgorithm::Bruck => assert_eq!(
                    msgs, lg,
                    "bruck at {n} ranks must send ceil(lg n) bundled frames per rank"
                ),
                _ => assert_eq!(
                    msgs,
                    (n - 1) as u64,
                    "{name} at {n} ranks must send n-1 messages per rank"
                ),
            }
        }
    }
    println!(
        "  structural: linear/pairwise total messages Θ(n²); bruck Θ(n·lg n) — sub-quadratic"
    );

    // Part B — bytes copied per collective write. The same collective
    // write runs against the staged fallback (single-device local
    // backend) and the zero-copy piece dispatch (plan-executing striped
    // backend); the `staging_copy_bytes` counter is the regression
    // guard: exactly the payload when staged, exactly zero when not.
    let ranks = 4usize;
    let per_rank = common::sz(1 << 20);
    let staged_of = |backend: Arc<dyn jpio::storage::Backend>, path: &str| -> u64 {
        threads::run(ranks, |c| {
            let f = File::open_with_backend(
                c,
                path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend.clone(),
            )
            .unwrap();
            let mine = vec![c.rank() as u8; per_rank];
            f.write_at_all((c.rank() * per_rank) as i64, mine.as_slice(), 0, per_rank, &Datatype::BYTE)
                .unwrap();
            let staged = f.stats().counter("staging_copy_bytes").sum;
            f.close().unwrap();
            staged
        })
        .into_iter()
        .sum()
    };
    let lpath = format!("/tmp/jpio-abl10-local-{}.dat", std::process::id());
    let spath = format!("/tmp/jpio-abl10-striped-{}.dat", std::process::id());
    let payload = (ranks * per_rank) as u64;
    let staged = staged_of(Arc::new(jpio::storage::local::LocalBackend::instant()), &lpath);
    let zero = staged_of(
        Arc::new(jpio::storage::striped::StripedBackend::local(4, 64 << 10)),
        &spath,
    );
    println!(
        "  collective write of {payload} B: staging copies — staged backend {staged} B, \
         striped (zero-copy) {zero} B"
    );
    assert_eq!(staged, payload, "staged fallback must copy each payload byte exactly once");
    assert_eq!(zero, 0, "zero-copy regression: striped collective write staged payload bytes");
    common::cleanup(&lpath);
    cleanup_striped(&spath, 4);
}

fn strided_write_behind() {
    println!("\n--- ablation 11: page cache write-behind for small strided writes ---");
    // Part A — bandwidth. On the Barq NFS model every write RPC pays
    // latency, so 4 KiB pieces written straight through lose badly;
    // absorbed by the page cache they coalesce into stripe-aligned
    // flushes at sync and approach the one-bulk-write ceiling.
    let region = common::sz(4 << 20);
    let piece = 4 << 10;
    let npieces = region / piece;
    let cached_info = || {
        Info::from([
            ("jpio_cache", "enable"),
            ("jpio_cache_size", "16777216"), // whole region resident
        ])
    };
    // Two interleaved passes (even pieces, then odd): the write order a
    // simple cursor never sees, which the dirty-page coalescer still
    // flushes as one run.
    let strided = |path: &str, info: Info| {
        threads::run(1, |c| {
            let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                std::sync::Arc::new(jpio::storage::nfs::NfsBackend::barq());
            let f =
                File::open_with_backend(c, path, amode::RDWR | amode::CREATE, info.clone(), backend)
                    .unwrap();
            for pass in 0..2usize {
                for p in (pass..npieces).step_by(2) {
                    let buf = vec![p as u8; piece];
                    f.write_at((p * piece) as i64, buf.as_slice(), 0, piece, &Datatype::BYTE)
                        .unwrap();
                }
            }
            f.sync().unwrap();
            f.close().unwrap();
        });
    };
    let path = format!("/tmp/jpio-abl11-{}.dat", std::process::id());
    let bulk = bench("bulk one write  ", 1, common::reps(), region, || {
        threads::run(1, |c| {
            let backend: std::sync::Arc<dyn jpio::storage::Backend> =
                std::sync::Arc::new(jpio::storage::nfs::NfsBackend::barq());
            let f = File::open_with_backend(
                c,
                &path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend,
            )
            .unwrap();
            let buf = vec![0u8; region];
            f.write_at(0, buf.as_slice(), 0, region, &Datatype::BYTE).unwrap();
            f.sync().unwrap();
            f.close().unwrap();
        });
    });
    let behind = bench("4K + write-behind", 1, common::reps(), region, || {
        strided(&path, cached_info());
    });
    let through = bench("4K uncached     ", 1, common::reps(), region, || {
        strided(&path, Info::null());
    });
    println!("  bulk one write    {:10.1} MB/s", bulk.mbs());
    println!("  4K + write-behind {:10.1} MB/s", behind.mbs());
    println!("  4K uncached       {:10.1} MB/s", through.mbs());
    println!(
        "  write-behind recovers {:.0}% of bulk ({:.1}x over uncached small writes)",
        100.0 * behind.mbs() / bulk.mbs(),
        behind.mbs() / through.mbs()
    );
    assert!(
        behind.mbs() >= 0.5 * bulk.mbs(),
        "write-behind small writes fell under 50% of bulk bandwidth"
    );
    common::cleanup(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));

    // Part B — equivalence guard on the instant local backend: the same
    // strided workload with the cache on and off must leave
    // byte-identical files, the cache-off run counting nothing and the
    // cache-on run visibly flushing through the write-behind path.
    let equiv = |path: &str, info: Info| -> (Vec<u8>, u64, u64) {
        let counters = threads::run(1, |c| {
            let f = File::open(c, path, amode::RDWR | amode::CREATE, info.clone()).unwrap();
            for pass in 0..2usize {
                for p in (pass..npieces).step_by(2) {
                    let buf = vec![(p * 7) as u8; piece];
                    f.write_at((p * piece) as i64, buf.as_slice(), 0, piece, &Datatype::BYTE)
                        .unwrap();
                }
            }
            f.sync().unwrap();
            let report = f.stats();
            let touched = ["cache_hit_bytes", "cache_miss_bytes", "rmw_cycles"]
                .iter()
                .map(|k| report.counter(k).sum)
                .sum::<u64>()
                + report.counter("write_behind_flush_bytes").sum;
            let flushed = report.counter("write_behind_flush_bytes").sum;
            f.close().unwrap();
            (touched, flushed)
        });
        let (touched, flushed) = counters[0];
        (std::fs::read(path).unwrap(), touched, flushed)
    };
    let pon = format!("/tmp/jpio-abl11-on-{}.dat", std::process::id());
    let poff = format!("/tmp/jpio-abl11-off-{}.dat", std::process::id());
    let (bytes_on, _, flushed_on) = equiv(&pon, cached_info());
    let (bytes_off, touched_off, _) = equiv(&poff, Info::null());
    assert_eq!(bytes_on, bytes_off, "jpio_cache=enable changed the bytes on disk");
    assert_eq!(touched_off, 0, "jpio_cache=disable must leave every cache counter at zero");
    assert!(flushed_on > 0, "cache-on run never flushed through write-behind");
    println!(
        "  equivalence: {} B byte-identical cache on/off; cache-off counters all zero, \
         cache-on flushed {flushed_on} B",
        bytes_on.len()
    );
    common::cleanup(&pon);
    common::cleanup(&poff);
    let _ = std::fs::remove_file(format!("{pon}.jpio-cache-lease"));
    let _ = std::fs::remove_file(format!("{poff}.jpio-cache-lease"));
}

fn dataset_vs_raw_views() {
    println!("\n--- ablation 12: dataset layer vs hand-rolled subarray views (NFS) ---");
    use jpio::comm::datatype::ArrayOrder;
    use jpio::dataset::Dataset;
    let ranks = 4;
    let n = if common::smoke() { 128usize } else { 512 }; // grid edge, ints
    let total = n * n * 4;
    let k = n * n / ranks;
    let raw_path = format!("/tmp/jpio-abl12-raw-{}.dat", std::process::id());
    let ds_path = format!("/tmp/jpio-abl12-ds-{}.jpds", std::process::id());
    let nfs = || -> std::sync::Arc<dyn jpio::storage::Backend> {
        std::sync::Arc::new(jpio::storage::nfs::NfsBackend::barq())
    };
    // Hand-rolled baseline: darray_block view + collective write.
    let raw = bench("raw views", 1, common::reps(), total, || {
        threads::run(ranks, |c| {
            let f = File::open_with_backend(
                c,
                &raw_path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                nfs(),
            )
            .unwrap();
            let r = c.rank();
            let ft = Datatype::darray_block(&[n, n], &[2, 2], r, ArrayOrder::C, &Datatype::INT)
                .unwrap();
            f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
            let mine = vec![r as i32; k];
            f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            f.close().unwrap();
        });
    });
    println!("  raw views   {:10.1} MB/s", raw.mbs());
    // Dataset layer: same decomposition through define mode + put_vara
    // (including the header round per repetition).
    let ds = bench("dataset", 1, common::reps(), total, || {
        threads::run(ranks, |c| {
            let f = File::open_with_backend(
                c,
                &ds_path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                nfs(),
            )
            .unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", n as u64).unwrap();
            let y = ds.def_dim("y", n as u64).unwrap();
            let v = ds.def_var("v", &Datatype::INT, "native", &[x, y]).unwrap();
            ds.enddef().unwrap();
            let r = c.rank();
            let (starts, subs) = Datatype::block_decompose(&[n, n], &[2, 2], r).unwrap();
            let mine = vec![r as i32; k];
            ds.put_vara(v, &starts, &subs, mine.as_slice()).unwrap();
            ds.close().unwrap();
        });
    });
    println!("  dataset     {:10.1} MB/s ({:.2}x raw)", ds.mbs(), raw.mbs() / ds.mbs());
    assert!(
        ds.mbs() >= raw.mbs() / 1.5,
        "dataset bandwidth {:.1} MB/s fell below 1/1.5 of raw views {:.1} MB/s",
        ds.mbs(),
        raw.mbs()
    );
    // Repeated same-shape put_vara must climb the plan cache: the
    // dataset hands the scheduler the same Arc'd view every time.
    let pc_path = format!("/tmp/jpio-abl12-pc-{}.jpds", std::process::id());
    let curves = {
        let pc_path = &pc_path;
        threads::run(ranks, move |c| {
            let f = File::open(c, pc_path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", n as u64).unwrap();
            let y = ds.def_dim("y", n as u64).unwrap();
            let v = ds.def_var("v", &Datatype::INT, "native", &[x, y]).unwrap();
            ds.enddef().unwrap();
            let (starts, subs) = Datatype::block_decompose(&[n, n], &[2, 2], c.rank()).unwrap();
            let mine = vec![c.rank() as i32; k];
            let mut hits = Vec::new();
            for _ in 0..4 {
                ds.put_vara(v, &starts, &subs, mine.as_slice()).unwrap();
                hits.push(ds.file().plan_cache_stats().hits);
            }
            ds.close().unwrap();
            hits
        })
    };
    let summed: Vec<u64> = (0..4).map(|i| curves.iter().map(|h| h[i]).sum()).collect();
    assert!(
        summed.windows(2).all(|w| w[1] > w[0]),
        "repeated same-shape put_vara must climb plan-cache hits: {summed:?}"
    );
    println!("  plan-cache hits across 4 repeated put_vara rounds: {summed:?}");
    common::cleanup(&raw_path);
    common::cleanup(&ds_path);
    common::cleanup(&pc_path);
}

fn elastic_rebuild_restore() {
    println!("\n--- ablation 13: kill → rebuild → bandwidth restored (striped parity) ---");
    use jpio::io::ErrorClass;
    use jpio::storage::faults::{FaultBackend, FaultPlan};
    use jpio::storage::layout::Redundancy;
    use jpio::storage::local::LocalBackend;
    use jpio::storage::striped::StripedBackend;
    use jpio::storage::{Backend, OpenOptions, StorageFile};
    use std::sync::Arc;

    let factor = 4usize;
    let victim = 1usize;
    let unit = 64u64 << 10;
    let total = common::sz(32 << 20);
    let path = format!("/tmp/jpio-abl13-{}.dat", std::process::id());
    let plan = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..factor)
        .map(|i| {
            if i == victim {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let b = StripedBackend::with_redundancy(children, unit, Redundancy::Parity).unwrap();
    let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    let chunk = vec![0xC7u8; (8 << 20).min(total)];
    let mut done = 0usize;
    while done < total {
        let n = chunk.len().min(total - done);
        f.write_at(done as u64, &chunk[..n]).unwrap();
        done += n;
    }

    let reps = common::reps().max(3); // the 90% gate below wants a stable median
    let read_pass = |label: &str| {
        bench(label, 1, reps, total, || {
            let mut buf = vec![0u8; (8 << 20).min(total)];
            let mut done = 0usize;
            while done < total {
                let n = buf.len().min(total - done);
                f.read_at(done as u64, &mut buf[..n]).unwrap();
                done += n;
            }
        })
    };

    let pre = read_pass("pre-kill");
    println!("  pre-kill read       {:10.1} MB/s", pre.mbs());

    // Failed-stop: every read of the victim's slots XOR-reconstructs.
    plan.inject_kill(ErrorClass::Io);
    let degraded = read_pass("degraded");
    let _ = f.take_advisories();
    assert!(
        f.backend_counters().degraded_reads > 0,
        "the degraded phase must actually reconstruct"
    );
    println!(
        "  degraded read       {:10.1} MB/s ({:.2}x pre-kill)",
        degraded.mbs(),
        degraded.mbs() / pre.mbs()
    );

    // Blank replacement behind the same slot, then rebuild.
    plan.revive();
    std::fs::OpenOptions::new()
        .write(true)
        .open(StripedBackend::object_path(&path, victim, factor))
        .unwrap()
        .set_len(0)
        .unwrap();
    let t0 = std::time::Instant::now();
    let rebuilt = f.rebuild_now().unwrap();
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    assert!(rebuilt > 0, "the blanked server must be detected and rebuilt");
    println!(
        "  rebuild             {:10.1} MB/s ({} B re-materialized)",
        rebuilt as f64 / 1e6 / dt,
        rebuilt
    );

    // The curve must come back: full bandwidth, zero reconstructions.
    let degraded_before = f.backend_counters().degraded_reads;
    let post = read_pass("post-rebuild");
    assert_eq!(
        f.backend_counters().degraded_reads,
        degraded_before,
        "post-rebuild reads must not reconstruct"
    );
    println!(
        "  post-rebuild read   {:10.1} MB/s ({:.2}x pre-kill)",
        post.mbs(),
        post.mbs() / pre.mbs()
    );
    assert!(
        post.mbs() >= 0.9 * pre.mbs(),
        "post-rebuild bandwidth {:.1} MB/s fell below 90% of pre-kill {:.1} MB/s",
        post.mbs(),
        pre.mbs()
    );
    drop(f);
    b.delete(&path).unwrap();
}

fn main() {
    println!("jpio ablation suite");
    per_item_vs_bulk();
    two_phase_on_off();
    sieving_stage_size();
    write_sieving_on_off();
    atomic_mode_cost();
    striped_storage_scaling();
    striped_alignment_on_off();
    striped_redundancy_modes();
    nonblocking_collective_overlap();
    plan_pipeline_parity();
    stats_instrumentation();
    scaleout_exchange_and_zero_copy();
    strided_write_behind();
    dataset_vs_raw_views();
    elastic_rebuild_restore();
    pjrt_pack_vs_rust();
    let _ = FigureReport::new("ablations", "case"); // keep the type exercised
    println!("\nablations done");
}
