//! Figure 4-4 — "Performance of tests using Java threads for parallel
//! access to a shared file residing on NFS storage attached to the
//! shared-memory machine".
//!
//! Same sweep as Fig 4-3 on the Barq NFS model. Expected shape (paper):
//!   * reads keep the local-disk trend (client page cache);
//!   * writes rise to ~250 MB/s aggregate (server absorbs into its
//!     cache), up from the 94 MB/s local device;
//!   * **mapped mode collapses** — the NFS client's lock-manager round
//!     trip per touched page serializes at the server ("the reasons for
//!     this can be locking (mapping) mechanisms used by Java for
//!     memory-mapped regions of a file residing on NFS storage").

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use jpio::bench::{FigureReport, Testbed};
use jpio::storage::nfs::NfsBackend;
use jpio::storage::Backend;

fn main() {
    println!("{}", Testbed::Barq);
    let styles = ["view_buffer", "mapped", "bulk"];
    common::check_styles(&styles);
    // Mapped mode pays per-4K-page costs; cap its share of the sweep so
    // the collapse is visible without dominating wall-clock.
    let total = (common::file_mb() << 20).min(256 << 20);
    let mapped_total = (total / 4).max(4 << 20);
    let threads = [1usize, 2, 4, 8];
    let path = format!("/tmp/jpio-fig44-{}.dat", std::process::id());
    let backend: Arc<dyn Backend> = Arc::new(NfsBackend::barq());
    common::prewrite(&backend, &path, total);

    let mut fig = FigureReport::new(
        format!("Figure 4-4: threads, shared file on NFS ({} MB)", total >> 20),
        "threads",
    );
    for dir in [false, true] {
        let dir_name = if dir { "write" } else { "read" };
        for style in styles {
            let bytes = if style == "mapped" { mapped_total } else { total };
            let mut points = Vec::new();
            for &t in &threads {
                let st =
                    common::thread_sweep_case(backend.clone(), &path, bytes, t, style, dir);
                println!(
                    "  {dir_name:>5} {style:<12} {t} threads: {:8.1} MB/s (median {:?})",
                    st.mbs(),
                    st.median()
                );
                points.push((t, st.mbs()));
            }
            fig.push(format!("{dir_name}/{style}"), points);
        }
    }
    println!("{}", fig.table());
    let csv = fig.write_csv("fig4_4_nfs_threads").unwrap();
    println!("csv: {csv}");

    // Shape assertions.
    let vb_w = fig.value("write/view_buffer", 8).unwrap();
    let mm_w = fig.value("write/mapped", 8).unwrap();
    if mm_w * 2.0 > vb_w {
        println!("!! SHAPE DRIFT: mapped-mode writes should collapse on NFS");
    }
    if !(120.0..=400.0).contains(&vb_w) {
        println!(
            "!! SHAPE DRIFT: NFS writes should plateau near the ~250 MB/s \
             server ingest (got {vb_w:.0})"
        );
    }
    common::cleanup(&path);
}
