//! Figure 4-5 — "Performance of tests using MPJ Express processes for
//! parallel access to shared file residing on NFS storage of the
//! Distributed Memory Machine".
//!
//! Sweep: 1..24 *processes* (fork + Unix-socket communicator, the MPJ
//! Express analogue) × {view_buffer, mapped, bulk} × {read, write} on
//! the RCMS NFS model. Expected shape (paper):
//!   * reads scale with client count (per-client caches) toward tens of
//!     GB/s aggregate at 24 processes; mapped slower than the other two;
//!   * writes: mapped mode *wins* (~375 MB/s — batched UNSTABLE
//!     write-back + COMMIT) over view_buffer/bulk (~275 MB/s stable
//!     ingest), with the jump appearing as processes grow.

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use jpio::bench::{FigureReport, Testbed};
use jpio::comm::{process, Comm};
use jpio::io::{amode, File, Info};
use jpio::storage::nfs::NfsBackend;
use jpio::storage::Backend;

fn proc_case(path: &str, total: usize, n: usize, style: &str, write: bool) -> f64 {
    // Time the I/O region *inside* the world and take the slowest rank
    // (the paper's methodology: bandwidth of the access itself, not of
    // process spawning); repeat and keep the best aggregate.
    let chunk = 8 << 20;
    let mut best = 0f64;
    for _ in 0..common::reps().min(3) {
        let io_secs = process::run_local(n, |c| {
            let backend: Arc<dyn Backend> = Arc::new(NfsBackend::rcms());
            let info = Info::from([("access_style", style)]);
            let f = File::open_with_backend(
                c,
                path,
                amode::RDWR | amode::CREATE,
                info,
                backend,
            )
            .unwrap();
            let (start, len) = jpio::bench::workload::partition(total, c.size(), c.rank());
            let mut buf = vec![0u8; chunk.min(len.max(1))];
            c.barrier();
            let t0 = std::time::Instant::now();
            let mut done = 0usize;
            while done < len {
                let nb = chunk.min(len - done);
                let off = (start as usize + done) as i64;
                if write {
                    f.write_at(off, &buf[..nb], 0, nb, &jpio::comm::Datatype::BYTE).unwrap();
                } else {
                    f.read_at(off, &mut buf[..nb], 0, nb, &jpio::comm::Datatype::BYTE)
                        .unwrap();
                }
                done += nb;
            }
            let mine = t0.elapsed().as_secs_f64();
            let slowest = c.allreduce_f64(jpio::comm::ReduceOp::Max, mine);
            f.close().unwrap();
            slowest
        });
        best = best.max(total as f64 / 1e6 / io_secs);
    }
    best
}

fn main() {
    println!("{}", Testbed::Rcms);
    let styles = ["view_buffer", "mapped", "bulk"];
    common::check_styles(&styles);
    let total = (common::file_mb() << 20).min(256 << 20);
    let mapped_total = (total / 4).max(4 << 20);
    let procs = [1usize, 4, 8, 16, 24];
    let path = format!("/tmp/jpio-fig45-{}.dat", std::process::id());
    {
        let backend: Arc<dyn Backend> = Arc::new(NfsBackend::rcms());
        common::prewrite(&backend, &path, total);
    }

    let mut fig = FigureReport::new(
        format!(
            "Figure 4-5: processes, shared file on cluster NFS ({} MB)",
            total >> 20
        ),
        "processes",
    );
    for dir in [false, true] {
        let dir_name = if dir { "write" } else { "read" };
        for style in styles {
            let bytes = if style == "mapped" { mapped_total } else { total };
            let mut points = Vec::new();
            for &n in &procs {
                let mbs = proc_case(&path, bytes, n, style, dir);
                println!("  {dir_name:>5} {style:<12} {n:>2} procs: {mbs:8.1} MB/s");
                points.push((n, mbs));
            }
            fig.push(format!("{dir_name}/{style}"), points);
        }
    }
    println!("{}", fig.table());
    let csv = fig.write_csv("fig4_5_cluster_nfs").unwrap();
    println!("csv: {csv}");

    // Shape assertions.
    let mm_w = fig.value("write/mapped", 24).unwrap();
    let vb_w = fig.value("write/view_buffer", 24).unwrap();
    if mm_w < vb_w {
        println!(
            "!! SHAPE DRIFT: mapped-mode write-back should win on the cluster \
             (got mapped {mm_w:.0} vs view_buffer {vb_w:.0})"
        );
    }
    let r1 = fig.value("read/view_buffer", 1).unwrap();
    let r24 = fig.value("read/view_buffer", 24).unwrap();
    if r24 < r1 * 2.0 {
        println!("!! SHAPE DRIFT: reads should scale with client count");
    }
    common::cleanup(&path);
}
