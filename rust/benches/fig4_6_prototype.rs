//! Figure 4-6 — "Read and write Test case results": the prototype's
//! `Perf.java` reproduced through the full MPJ-IO API.
//!
//! "First, the simple read and write operations are performed without
//! sync() method call and performance is evaluated in MB/s. After this
//! operation, the same performance evaluation is done with the sync()
//! method call and the program outputs the numbers in MB/s."
//!
//! Four ranks drive blocking `read`/`write` through individual file
//! pointers (1 KiB buffers, as in the paper's §3.6 test cases), with and
//! without a `sync()` after every write / before every read.

#[path = "common.rs"]
mod common;

use jpio::bench::{bench, FigureReport, Testbed};
use jpio::comm::{threads, Comm, Datatype};
use jpio::io::{amode, seek, File, Info};

const BUF_BYTES: usize = 1024; // the paper's 1 KB buffer
const OPS: usize = 2048; // ops per rank per repetition

fn perf_case(path: &str, ranks: usize, write: bool, with_sync: bool) -> f64 {
    let total = ranks * OPS * BUF_BYTES;
    let stats = bench(
        format!("{}{}", if write { "write" } else { "read" }, if with_sync { "+sync" } else { "" }),
        1,
        common::reps(),
        total,
        || {
            threads::run(ranks, |c| {
                let f = File::open(c, path, amode::RDWR | amode::CREATE, Info::null())
                    .unwrap();
                f.seek((c.rank() * OPS * BUF_BYTES) as i64, seek::SET).unwrap();
                let mut buf = vec![0u8; BUF_BYTES];
                for _ in 0..OPS {
                    if write {
                        f.write(buf.as_slice(), 0, BUF_BYTES, &Datatype::BYTE).unwrap();
                        if with_sync {
                            f.sync().unwrap();
                        }
                    } else {
                        if with_sync {
                            f.sync().unwrap();
                        }
                        f.read(buf.as_mut_slice(), 0, BUF_BYTES, &Datatype::BYTE).unwrap();
                    }
                }
                f.close().unwrap();
            });
        },
    );
    stats.mbs()
}

fn main() {
    println!("{}", Testbed::Barq);
    println!(
        "Figure 4-6: prototype Perf test — {} ranks, {} x {} B blocking ops each\n",
        4, OPS, BUF_BYTES
    );
    let path = format!("/tmp/jpio-fig46-{}.dat", std::process::id());

    let mut fig = FigureReport::new("Figure 4-6: read/write MB/s with and without sync()", "case");
    let cases = [
        ("write", true, false),
        ("write+sync", true, true),
        ("read", false, false),
        ("read+sync", false, true),
    ];
    let mut points = Vec::new();
    for (i, &(name, w, s)) in cases.iter().enumerate() {
        let mbs = perf_case(&path, 4, w, s);
        println!("  {name:<12} {mbs:10.1} MB/s");
        points.push((i + 1, mbs));
    }
    fig.push("MB/s", points.clone());
    println!("{}", fig.table());
    println!("  (case 1=write 2=write+sync 3=read 4=read+sync)");
    let csv = fig.write_csv("fig4_6_prototype").unwrap();
    println!("csv: {csv}");

    // Shape: sync() must cost something on writes; reads dominate writes.
    let w = points[0].1;
    let ws = points[1].1;
    if ws > w {
        println!("!! SHAPE DRIFT: write+sync should not beat plain write");
    }
    common::cleanup(&path);
}
