//! Figure 4-3 — "Performance of tests using Java threads for parallel
//! access to a shared file on local disk".
//!
//! Sweep: 1..8 threads × {view_buffer, mapped, bulk} × {read, write} on
//! the Barq local-disk model. Expected shape (paper):
//!   * reads reach multi-GB/s from the page cache, view_buffer on top
//!     (~10 GB/s at 1 GiB scale), mapped ~6 GB/s;
//!   * writes plateau at the device limit (~94 MB/s) regardless of
//!     thread count.
//!
//! `JPIO_BENCH_FULL=1` runs the paper-scale 1 GiB file.

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use jpio::bench::{FigureReport, Testbed};
use jpio::storage::local::LocalBackend;
use jpio::storage::Backend;

fn main() {
    println!("{}", Testbed::Barq);
    let styles = ["view_buffer", "mapped", "bulk"];
    common::check_styles(&styles);
    let total = common::file_mb() << 20;
    let threads = [1usize, 2, 4, 8];
    let path = format!("/tmp/jpio-fig43-{}.dat", std::process::id());
    let backend: Arc<dyn Backend> = Arc::new(LocalBackend::barq());
    common::prewrite(&backend, &path, total);

    let mut fig = FigureReport::new(
        format!(
            "Figure 4-3: threads, shared file on local disk ({} MB)",
            total >> 20
        ),
        "threads",
    );
    for dir in [false, true] {
        let dir_name = if dir { "write" } else { "read" };
        for style in styles {
            let mut points = Vec::new();
            for &t in &threads {
                let st = common::thread_sweep_case(
                    backend.clone(),
                    &path,
                    total,
                    t,
                    style,
                    dir,
                );
                println!(
                    "  {dir_name:>5} {style:<12} {t} threads: {:8.1} MB/s (median {:?})",
                    st.mbs(),
                    st.median()
                );
                points.push((t, st.mbs()));
            }
            fig.push(format!("{dir_name}/{style}"), points);
        }
    }
    println!("{}", fig.table());
    let csv = fig.write_csv("fig4_3_local_disk").unwrap();
    println!("csv: {csv}");

    // Shape assertions (who wins / plateaus) — soft-checked, loud on drift.
    let w1 = fig.value("write/view_buffer", 1).unwrap();
    let w8 = fig.value("write/view_buffer", 8).unwrap();
    if w8 > w1 * 2.0 {
        println!("!! SHAPE DRIFT: writes should plateau at the device limit");
    }
    let r8 = fig.value("read/view_buffer", 8).unwrap();
    if r8 < w8 {
        println!("!! SHAPE DRIFT: page-cache reads should beat device writes");
    }
    common::cleanup(&path);
}
