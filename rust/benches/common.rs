//! Shared plumbing for the figure benches (`#[path]`-included by each
//! bench binary; not a crate of its own).
#![allow(dead_code)] // each bench binary uses a different subset

use std::sync::Arc;

use jpio::bench::{bench, BenchStats};
use jpio::comm::{threads, Comm};
use jpio::io::{amode, File, Info};
use jpio::storage::{Backend, StorageFile};
use jpio::strategy;

/// Per-worker payload bytes for the sweep. The paper used a 1 GiB file;
/// the default here keeps the full suite under a few minutes — set
/// `JPIO_BENCH_FULL=1` to run at paper scale.
pub fn file_mb() -> usize {
    if std::env::var("JPIO_BENCH_FULL").is_ok() {
        1024
    } else {
        std::env::var("JPIO_BENCH_MB").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
    }
}

/// Repetitions per case.
pub fn reps() -> usize {
    if smoke() {
        1
    } else if std::env::var("JPIO_BENCH_FULL").is_ok() {
        5
    } else {
        3
    }
}

/// CI smoke mode (`JPIO_SMOKE=1`): tiny sizes, one repetition — the
/// bench code compiles *and runs* on every PR without burning minutes.
pub fn smoke() -> bool {
    std::env::var("JPIO_SMOKE").is_ok()
}

/// Scale a workload size down 16× in smoke mode (floor 1).
pub fn sz(full: usize) -> usize {
    if smoke() {
        (full / 16).max(1)
    } else {
        full
    }
}

/// Measured aggregate bandwidth of `t` thread-ranks each moving its
/// disjoint partition of a shared file with `style`, on `backend`.
/// `write` selects direction. Returns MB/s.
pub fn thread_sweep_case(
    backend: Arc<dyn Backend>,
    path: &str,
    total_bytes: usize,
    t: usize,
    style: &str,
    write: bool,
) -> BenchStats {
    let chunk = 8 << 20; // I/O call granularity (8 MiB per call)
    let stats = bench(
        format!("{style}/{t}t/{}", if write { "write" } else { "read" }),
        1,
        reps(),
        total_bytes,
        || {
            threads::run(t, |c| {
                let info = Info::from([("access_style", style)]);
                let f = File::open_with_backend(
                    c,
                    path,
                    amode::RDWR | amode::CREATE,
                    info,
                    backend.clone(),
                )
                .unwrap();
                let (start, len) =
                    jpio::bench::workload::partition(total_bytes, c.size(), c.rank());
                let mut buf = vec![0u8; chunk.min(len)];
                let mut done = 0usize;
                while done < len {
                    let n = chunk.min(len - done);
                    let off = (start as usize + done) as i64;
                    if write {
                        f.write_at(off, &buf[..n], 0, n, &jpio::comm::Datatype::BYTE)
                            .unwrap();
                    } else {
                        f.read_at(off, &mut buf[..n], 0, n, &jpio::comm::Datatype::BYTE)
                            .unwrap();
                    }
                    done += n;
                }
                f.close().unwrap();
            });
        },
    );
    stats
}

/// Validate that a strategy name resolves (guards against typos in sweeps).
pub fn check_styles(styles: &[&str]) {
    for s in styles {
        strategy::by_name(s).unwrap();
    }
}

/// Delete a bench file + its sidecar.
pub fn cleanup(path: &str) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

/// Prepare a file of `bytes` (so read sweeps have data and the page cache
/// is warm, matching the paper's read-after-write methodology).
pub fn prewrite(backend: &Arc<dyn Backend>, path: &str, bytes: usize) {
    let f: Arc<dyn StorageFile> = backend
        .open(path, jpio::storage::OpenOptions::rw_create())
        .unwrap();
    let chunk = vec![0xA5u8; 8 << 20];
    let mut done = 0;
    while done < bytes {
        let n = chunk.len().min(bytes - done);
        f.write_at(done as u64, &chunk[..n]).unwrap();
        done += n;
    }
    f.sync().unwrap();
}
