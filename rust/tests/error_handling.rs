//! Error handling (§7.2.7/7.2.8) under fault injection: failures must
//! surface with the correct MPI error class, not corrupt library state,
//! and the handle must stay usable afterwards.

use std::sync::Arc;

use jpio::comm::{threads, Comm, Datatype};
use jpio::io::{amode, ErrorClass, File, Info, IoError};
use jpio::storage::faults::{FaultBackend, FaultOp, FaultPlan, FaultRule};
use jpio::storage::local::LocalBackend;
use jpio::storage::{Backend, OpenOptions, StorageFile};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-errors-{}-{name}", std::process::id())
}

fn faulty_backend(rules: Vec<FaultRule>) -> Arc<FaultBackend<LocalBackend>> {
    Arc::new(FaultBackend::new(LocalBackend::instant(), FaultPlan::new(rules)))
}

#[test]
fn write_fault_surfaces_class_and_handle_survives() {
    let path = tmp("writefault");
    let backend = faulty_backend(vec![FaultRule::once(FaultOp::Write, 1, ErrorClass::NoSpace)]);
    threads::run(1, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        let data = vec![1u8; 64];
        f.write_at(0, data.as_slice(), 0, 64, &Datatype::BYTE).unwrap(); // #0 ok
        let err = f.write_at(64, data.as_slice(), 0, 64, &Datatype::BYTE).unwrap_err();
        assert_eq!(err.class, ErrorClass::NoSpace);
        assert!(err.to_string().contains("MPI_ERR_NO_SPACE"));
        // Handle still usable.
        f.write_at(64, data.as_slice(), 0, 64, &Datatype::BYTE).unwrap();
        let mut back = vec![0u8; 128];
        f.read_at(0, back.as_mut_slice(), 0, 128, &Datatype::BYTE).unwrap();
        assert!(back.iter().all(|&b| b == 1));
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn read_fault_in_nonblocking_op_propagates_through_request() {
    let path = tmp("ireadfault");
    let backend = faulty_backend(vec![FaultRule::once(FaultOp::Read, 0, ErrorClass::Io)]);
    threads::run(1, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        f.write_at(0, vec![3u8; 32].as_slice(), 0, 32, &Datatype::BYTE).unwrap();
        let req = f.iread_at(0, vec![0u8; 32], 0, 32, &Datatype::BYTE).unwrap();
        let err = req.wait().unwrap_err();
        assert_eq!(err.class, ErrorClass::Io);
        // Second attempt (rule fired once) succeeds.
        let req = f.iread_at(0, vec![0u8; 32], 0, 32, &Datatype::BYTE).unwrap();
        let (st, buf) = req.wait().unwrap();
        assert_eq!(st.bytes, 32);
        assert!(buf.iter().all(|&b| b == 3));
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn sync_fault_is_reported() {
    let path = tmp("syncfault");
    let backend = faulty_backend(vec![FaultRule::once(FaultOp::Sync, 0, ErrorClass::Quota)]);
    threads::run(1, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        assert_eq!(f.sync().unwrap_err().class, ErrorClass::Quota);
        f.sync().unwrap();
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn fault_during_split_collective_write() {
    let path = tmp("splitfault");
    // Fail the second storage write: first collective write succeeds,
    // second one's END reports the error.
    let backend = faulty_backend(vec![FaultRule::once(FaultOp::Write, 1, ErrorClass::NoSpace)]);
    threads::run(1, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        let d = vec![1i32; 256];
        f.write_at_all_begin(0, d.as_slice(), 0, 256, &Datatype::INT).unwrap();
        f.write_at_all_end().unwrap();
        // On a single rank the collective degenerates to an independent
        // write performed at BEGIN; on larger worlds the storage phase
        // runs on the engine and the error surfaces at END. Accept both.
        let err = match f.write_at_all_begin(256, d.as_slice(), 0, 256, &Datatype::INT) {
            Err(e) => e,
            Ok(()) => f.write_at_all_end().unwrap_err(),
        };
        assert_eq!(err.class, ErrorClass::NoSpace);
        // Handle reusable after the failed split op.
        f.write_at_all_begin(256, d.as_slice(), 0, 256, &Datatype::INT).unwrap();
        f.write_at_all_end().unwrap();
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn open_error_classes() {
    threads::run(1, |c| {
        // Missing file.
        let err = File::open(c, "/tmp/jpio-no-such-file-xyz", amode::RDWR, Info::null())
            .map(|_| ())
            .unwrap_err();
        assert_eq!(err.class, ErrorClass::NoSuchFile);
        // Invalid amode.
        let err = File::open(c, "/tmp/x", amode::RDONLY | amode::CREATE, Info::null())
            .map(|_| ())
            .unwrap_err();
        assert_eq!(err.class, ErrorClass::Amode);
        // Unknown backend hint.
        let err = File::open(
            c,
            "/tmp/x",
            amode::RDWR | amode::CREATE,
            Info::from([("jpio_backend", "punchcards")]),
        )
        .map(|_| ())
        .unwrap_err();
        assert_eq!(err.class, ErrorClass::Arg);
    });
}

/// A backend whose every `open` fails with `MPI_ERR_FILE`.
struct FailingOpenBackend;

impl Backend for FailingOpenBackend {
    fn open(&self, _path: &str, _opts: OpenOptions) -> jpio::io::errors::Result<Arc<dyn StorageFile>> {
        Err(IoError::new(ErrorClass::File, "injected open failure"))
    }

    fn delete(&self, _path: &str) -> jpio::io::errors::Result<()> {
        Ok(())
    }

    fn name(&self) -> &'static str {
        "failing-open"
    }
}

#[test]
fn collective_open_failure_reports_file_error_on_all_ranks() {
    // Regression: the rank-0 success broadcast used to hand the
    // communicator a discarded temporary as its flag buffer. With a real
    // buffer on both sides, a failed rank-0 open must surface
    // MPI_ERR_FILE on *every* rank — rank 0 from the backend, the rest
    // from the broadcast flag — instead of hanging or misreading the
    // flag.
    threads::run(3, |c| {
        let err = File::open_with_backend(
            c,
            "/tmp/jpio-failing-open.dat",
            amode::RDWR | amode::CREATE,
            Info::null(),
            Arc::new(FailingOpenBackend),
        )
        .map(|_| ())
        .unwrap_err();
        assert_eq!(err.class, ErrorClass::File, "rank {} got {:?}", c.rank(), err.class);
    });
    let _ = std::fs::remove_file("/tmp/jpio-failing-open.dat.jpio-sfp");
}

#[test]
fn collective_open_failure_propagates_to_all_ranks() {
    // Rank 0 fails the create (missing directory); every rank must get an
    // error, not a hang.
    threads::run(3, |c| {
        let err = File::open(
            c,
            "/tmp/jpio-missing-dir-abc/file.dat",
            amode::RDWR | amode::CREATE,
            Info::null(),
        )
        .map(|_| ())
        .unwrap_err();
        assert!(
            err.class == ErrorClass::NoSuchFile || err.class == ErrorClass::File,
            "rank {} got {:?}",
            c.rank(),
            err.class
        );
    });
}
