//! Distributed-memory integration: the full io layer across *forked
//! processes* (the paper's MPJ Express configuration), including the NFS
//! backend, collective I/O, shared pointers and ordered writes — the
//! paths where cross-address-space coordination (flock sidecars, the
//! socket-mesh communicator) actually matters.

use jpio::comm::{process, Comm, Datatype};
use jpio::io::{amode, File, Info};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-multiproc-{}-{name}", std::process::id())
}

#[test]
fn collective_write_read_across_processes() {
    let path = tmp("coll");
    process::run_local(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let mine: Vec<i32> = (0..512).map(|i| (r * 512 + i) as i32).collect();
        f.write_at_all((r * 512) as i64, mine.as_slice(), 0, 512, &Datatype::INT).unwrap();
        c.barrier();
        let n = 512 * c.size();
        let mut all = vec![0i32; n];
        f.read_at_all(0, all.as_mut_slice(), 0, n, &Datatype::INT).unwrap();
        assert_eq!(all, (0..n as i32).collect::<Vec<_>>());
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn strided_two_phase_across_processes() {
    let path = tmp("strided");
    process::run_local(3, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let n = c.size();
        let r = c.rank();
        let slot = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&slot, 0, (n * 4) as i64).unwrap();
        f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        let k = 300;
        let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
        f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
        c.barrier();
        let mut back = vec![0i32; k];
        f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
        assert_eq!(back, mine);
        f.close().unwrap();
    });
    let raw = std::fs::read(&path).unwrap();
    let ints: Vec<i32> =
        raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
    assert_eq!(ints, (0..900).collect::<Vec<_>>());
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn shared_pointer_across_processes() {
    // The sidecar flock fetch-and-add must serialize across address
    // spaces, not just threads.
    let path = tmp("sfp");
    process::run_local(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let mine = vec![c.rank() as i32; 64];
        for _ in 0..4 {
            f.write_shared(mine.as_slice(), 0, 64, &Datatype::INT).unwrap();
        }
        c.barrier();
        assert_eq!(f.get_position_shared().unwrap(), 4 * 4 * 64);
        f.close().unwrap();
    });
    let raw = std::fs::read(&path).unwrap();
    let ints: Vec<i32> =
        raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
    let mut counts = [0usize; 4];
    for run in ints.chunks_exact(64) {
        assert!(run.iter().all(|&v| v == run[0]), "interleaved shared append");
        counts[run[0] as usize] += 1;
    }
    assert_eq!(counts, [4, 4, 4, 4]);
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn ordered_write_across_processes() {
    let path = tmp("ordered");
    process::run_local(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let mine = vec![c.rank() as i32; (c.rank() + 1) * 8];
        f.write_ordered(mine.as_slice(), 0, mine.len(), &Datatype::INT).unwrap();
        f.close().unwrap();
    });
    let raw = std::fs::read(&path).unwrap();
    let ints: Vec<i32> =
        raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
    let mut want = Vec::new();
    for r in 0..4 {
        want.extend(std::iter::repeat(r as i32).take((r + 1) * 8));
    }
    assert_eq!(ints, want);
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn nfs_backend_across_processes() {
    // Full protocol paths (chunked writes, server lock, COMMIT) across
    // processes, instant cost profile.
    let path = tmp("nfs");
    process::run_local(3, |c| {
        let info = Info::from([("jpio_backend", "nfs")]);
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
        let mine = vec![c.rank() as u8; 128 * 1024];
        f.write_at((c.rank() * 128 * 1024) as i64, mine.as_slice(), 0, mine.len(), &Datatype::BYTE)
            .unwrap();
        f.sync().unwrap();
        c.barrier();
        let n = 128 * 1024;
        let mut peer = vec![0u8; n];
        let p = (c.rank() + 1) % c.size();
        f.read_at((p * n) as i64, peer.as_mut_slice(), 0, n, &Datatype::BYTE)
            .unwrap();
        assert!(peer.iter().all(|&v| v == p as u8));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn atomic_mode_across_processes() {
    let path = tmp("atomic");
    process::run_local(3, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_atomicity(true).unwrap();
        let mine = vec![c.rank() as i32 + 10; 2048];
        for _ in 0..5 {
            f.write_at(0, mine.as_slice(), 0, 2048, &Datatype::INT).unwrap();
        }
        c.barrier();
        let mut back = vec![0i32; 2048];
        f.read_at(0, back.as_mut_slice(), 0, 2048, &Datatype::INT).unwrap();
        assert!(back.windows(2).all(|w| w[0] == w[1]), "torn cross-process atomic write");
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn delete_on_close_across_processes() {
    let path = tmp("doc");
    process::run_local(2, |c| {
        let f = File::open(
            c,
            &path,
            amode::RDWR | amode::CREATE | amode::DELETE_ON_CLOSE,
            Info::null(),
        )
        .unwrap();
        f.write_at(0, b"temp".as_slice(), 0, 4, &Datatype::BYTE).unwrap();
        f.close().unwrap();
    });
    assert!(!std::path::Path::new(&path).exists());
}
