//! Model-based differential testing: random operation sequences against
//! an in-memory mirror of the file.
//!
//! A single rank drives randomized `write_at`/`read_at`/`write`/`read`/
//! `seek`/`set_size`/`write_shared`/view changes against both the real
//! `File` and a `Vec<u8>` model that implements POSIX semantics (sparse
//! zero fill, EOF-short reads). After every read the two must agree; at
//! the end the raw file must equal the model byte-for-byte.
//!
//! This is the invariant net under the whole flattening/strategy/pointer
//! machinery — any disagreement between the view math and the actual byte
//! placement shows up here with a reproducible seed.

use jpio::comm::datatype::Datatype;
use jpio::comm::threads;
use jpio::io::{amode, seek, File, Info};
use jpio::testing::SplitMix64;

/// In-memory POSIX-file model.
struct ModelFile {
    data: Vec<u8>,
}

impl ModelFile {
    fn new() -> Self {
        ModelFile { data: Vec::new() }
    }

    fn write_at(&mut self, off: usize, buf: &[u8]) {
        if self.data.len() < off + buf.len() {
            self.data.resize(off + buf.len(), 0);
        }
        self.data[off..off + buf.len()].copy_from_slice(buf);
    }

    fn read_at(&self, off: usize, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len];
        if off < self.data.len() {
            let n = (self.data.len() - off).min(len);
            out[..n].copy_from_slice(&self.data[off..off + n]);
        }
        out
    }

    fn visible(&self, off: usize, len: usize) -> usize {
        self.data.len().saturating_sub(off).min(len)
    }

    fn set_size(&mut self, size: usize) {
        self.data.resize(size, 0);
    }
}

fn run_stress(seed: u64, strategy: &str) {
    let path = format!("/tmp/jpio-stress-{}-{seed}-{strategy}", std::process::id());
    let strategy = strategy.to_string();
    let p = path.clone();
    threads::run(1, move |c| {
        let info = Info::from([("access_style", strategy.as_str())]);
        let f = File::open(c, &p, amode::RDWR | amode::CREATE, info).unwrap();
        let mut model = ModelFile::new();
        let mut rng = SplitMix64::new(seed);
        let mut ptr = 0usize; // mirror of the individual pointer (bytes)
        for step in 0..400 {
            match rng.below(8) {
                // write_at
                0 | 1 => {
                    let off = rng.range(0, 4096);
                    let len = rng.range(1, 512);
                    let mut buf = vec![0u8; len];
                    rng.fill_bytes(&mut buf);
                    let st = f.write_at(off as i64, buf.as_slice(), 0, len, &Datatype::BYTE)
                        .unwrap();
                    assert_eq!(st.bytes, len);
                    model.write_at(off, &buf);
                }
                // read_at
                2 | 3 => {
                    let off = rng.range(0, 5000);
                    let len = rng.range(1, 512);
                    let mut buf = vec![0xABu8; len];
                    let st = f.read_at(off as i64, buf.as_mut_slice(), 0, len, &Datatype::BYTE)
                        .unwrap();
                    let want_bytes = model.visible(off, len);
                    assert_eq!(st.bytes, want_bytes, "step {step} read_at count (seed {seed:#x})");
                    let want = model.read_at(off, len);
                    assert_eq!(
                        &buf[..want_bytes],
                        &want[..want_bytes],
                        "step {step} read_at data (seed {seed:#x})"
                    );
                }
                // sequential write via individual pointer
                4 => {
                    let len = rng.range(1, 256);
                    let mut buf = vec![0u8; len];
                    rng.fill_bytes(&mut buf);
                    f.write(buf.as_slice(), 0, len, &Datatype::BYTE).unwrap();
                    model.write_at(ptr, &buf);
                    ptr += len;
                    assert_eq!(f.get_position().unwrap(), ptr as i64);
                }
                // sequential read via individual pointer
                5 => {
                    let len = rng.range(1, 256);
                    let mut buf = vec![0u8; len];
                    let st = f.read(buf.as_mut_slice(), 0, len, &Datatype::BYTE).unwrap();
                    let want_bytes = model.visible(ptr, len);
                    assert_eq!(st.bytes, want_bytes, "step {step} read count (seed {seed:#x})");
                    let want = model.read_at(ptr, len);
                    assert_eq!(&buf[..want_bytes], &want[..want_bytes]);
                    ptr += want_bytes;
                }
                // seek
                6 => {
                    let target = rng.range(0, 4096);
                    f.seek(target as i64, seek::SET).unwrap();
                    ptr = target;
                }
                // resize (grow or shrink)
                _ => {
                    let size = rng.range(0, 6000);
                    f.set_size(size as i64).unwrap();
                    model.set_size(size);
                }
            }
        }
        // Final: whole-file comparison.
        let fsize = f.get_size().unwrap() as usize;
        assert_eq!(fsize, model.data.len(), "final size (seed {seed:#x})");
        let mut all = vec![0u8; fsize];
        if fsize > 0 {
            f.read_at(0, all.as_mut_slice(), 0, fsize, &Datatype::BYTE).unwrap();
        }
        assert_eq!(all, model.data, "final contents (seed {seed:#x})");
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn stress_view_buffer() {
    for seed in [1, 2, 3, 0xDEAD] {
        run_stress(seed, "view_buffer");
    }
}

#[test]
fn stress_bulk() {
    for seed in [4, 5, 0xBEEF] {
        run_stress(seed, "bulk");
    }
}

#[test]
fn stress_data_sieving() {
    for seed in [6, 7, 0xCAFE] {
        run_stress(seed, "data_sieving");
    }
}

#[test]
fn stress_per_item() {
    run_stress(8, "per_item"); // slow strategy: one seed suffices
}

/// Same differential net through a *strided view*: writes through the
/// view land at the flattened positions the model predicts.
#[test]
fn stress_strided_view_against_model() {
    let path = format!("/tmp/jpio-stress-view-{}", std::process::id());
    let p = path.clone();
    threads::run(1, move |c| {
        let f = File::open(c, &p, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let mut rng = SplitMix64::new(0x57EED);
        for round in 0..30 {
            // Random interleave geometry.
            let nslots = rng.range(2, 5);
            let myslot = rng.range(0, nslots - 1);
            let blocklen = rng.range(1, 4);
            let cell = Datatype::vector(1, blocklen, blocklen as i64, &Datatype::INT).unwrap();
            let ft =
                Datatype::resized(&cell, 0, (nslots * blocklen * 4) as i64).unwrap();
            f.set_view(
                (myslot * blocklen * 4) as i64,
                &Datatype::INT,
                &ft,
                "native",
                &Info::null(),
            )
            .unwrap();
            let k = rng.range(1, 40);
            let vals: Vec<i32> = (0..k).map(|_| rng.next_u64() as i32).collect();
            let off = rng.range(0, 20) as i64;
            f.write_at(off, vals.as_slice(), 0, k, &Datatype::INT).unwrap();
            // Model: compute expected absolute int positions.
            let frame = nslots * blocklen;
            let mut expected = Vec::with_capacity(k);
            for i in 0..k {
                let e = off as usize + i;
                let inst = e / blocklen;
                let inner = e % blocklen;
                expected.push(inst * frame + myslot * blocklen + inner);
            }
            // Verify through a flat view read.
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            for (i, &pos) in expected.iter().enumerate() {
                let mut one = [0i32];
                f.read_at(pos as i64, one.as_mut_slice(), 0, 1, &Datatype::INT).unwrap();
                assert_eq!(one[0], vals[i], "round {round} element {i} at int {pos}");
            }
        }
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}
