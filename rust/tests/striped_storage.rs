//! Striped-backend integration: stripe-boundary semantics, distributed
//! metadata, fault surfacing, all four access strategies, and the paper's
//! §3.6 scenarios rerun against a `StripedBackend` with ≥ 4 servers —
//! across thread ranks *and* forked-process ranks.

use std::sync::Arc;

use jpio::comm::{process, threads, Comm, Datatype};
use jpio::io::{amode, ErrorClass, File, Info};
use jpio::storage::faults::{FaultBackend, FaultOp, FaultPlan, FaultRule};
use jpio::storage::local::LocalBackend;
use jpio::storage::nfs::NfsConfig;
use jpio::storage::striped::StripedBackend;
use jpio::storage::{Backend, MappedRegion, OpenOptions, StorageFile};
use jpio::strategy::{self, AccessStrategy, ALL_STRATEGIES};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-stripetest-{}-{name}", std::process::id())
}

fn striped4(unit: u64) -> StripedBackend {
    StripedBackend::local(4, unit)
}

/// Remove a logical striped file's objects + the io-layer sidecar.
fn cleanup(path: &str, servers: usize) {
    for s in 0..servers {
        let _ = std::fs::remove_file(StripedBackend::object_path(path, s, servers));
    }
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

// ----------------------------------------------------------------------
// Stripe-boundary semantics (raw Backend/StorageFile surface)
// ----------------------------------------------------------------------

#[test]
fn rw_spanning_multiple_stripe_units() {
    let b = striped4(16);
    let path = tmp("span");
    let f: Arc<dyn StorageFile> = b.open(&path, OpenOptions::rw_create()).unwrap();
    let data: Vec<u8> = (0..200u8).collect();
    f.write_at(9, &data).unwrap(); // crosses 13 unit boundaries
    assert_eq!(f.size().unwrap(), 209);
    let mut back = vec![0u8; 200];
    assert_eq!(f.read_at(9, &mut back).unwrap(), 200);
    assert_eq!(back, data);
    // Every server holds part of the file.
    for s in 0..4 {
        let len = std::fs::metadata(StripedBackend::object_path(&path, s, 4)).unwrap().len();
        assert!(len > 0, "server {s} got no data");
    }
    b.delete(&path).unwrap();
}

#[test]
fn zero_length_ops_at_stripe_boundary() {
    let b = striped4(32);
    let path = tmp("zero");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.write_at(0, &[7u8; 64]).unwrap();
    // Zero-length write/read exactly on a boundary: no-ops, no error.
    assert_eq!(f.write_at(32, &[]).unwrap(), 0);
    let mut empty = [0u8; 0];
    assert_eq!(f.read_at(32, &mut empty).unwrap(), 0);
    assert_eq!(f.size().unwrap(), 64);
    // A zero-length run inside a vectored read is complete, not short.
    let mut buf = [0u8; 4];
    assert_eq!(f.read_runs(&[(32, 0), (0, 4)], &mut buf).unwrap(), 4);
    assert_eq!(buf, [7u8; 4]);
    b.delete(&path).unwrap();
}

#[test]
fn set_size_shrinks_across_servers() {
    let b = striped4(10);
    let path = tmp("shrink");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.write_at(0, &vec![1u8; 200]).unwrap(); // 50 bytes per server
    f.set_size(45).unwrap(); // 4 full units + 5 → objects 15, 10, 10, 10
    assert_eq!(f.size().unwrap(), 45);
    for (s, want) in [(0usize, 15u64), (1, 10), (2, 10), (3, 10)] {
        let len = std::fs::metadata(StripedBackend::object_path(&path, s, 4)).unwrap().len();
        assert_eq!(len, want, "server {s} object size after shrink");
    }
    let mut buf = vec![0xEEu8; 100];
    assert_eq!(f.read_at(0, &mut buf).unwrap(), 45);
    assert!(buf[..45].iter().all(|&v| v == 1));
    // Growing back exposes zeros, not stale bytes.
    f.set_size(80).unwrap();
    assert_eq!(f.size().unwrap(), 80);
    let mut buf = vec![0xEEu8; 80];
    assert_eq!(f.read_at(0, &mut buf).unwrap(), 80);
    assert!(buf[45..].iter().all(|&v| v == 0), "grown region must read zero");
    b.delete(&path).unwrap();
}

#[test]
fn vectored_read_stops_at_logical_eof() {
    let b = striped4(8);
    let path = tmp("eofruns");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.write_at(0, &[9u8; 20]).unwrap();
    let mut buf = [0u8; 30];
    // Second run crosses EOF (20): 4 of 10 bytes; third must not run.
    let got = f.read_runs(&[(0, 10), (16, 10), (40, 10)], &mut buf).unwrap();
    assert_eq!(got, 14);
    assert_eq!(&buf[..14], &[9u8; 14]);
    assert_eq!(&buf[14..], &[0u8; 16]);
    b.delete(&path).unwrap();
}

#[test]
fn unsorted_vectored_read_over_sparse_objects_keeps_all_data() {
    // Server 0's stripe object is short (only logical [0, 5) written on
    // it) while the logical file extends to 99 via server 1. A vectored
    // read whose runs arrive in descending child order on server 0 —
    // first the hole at logical 40, then the real data at logical 0 —
    // must still return the real bytes: the per-server batch has to be
    // issued in ascending child order or the child's short read at the
    // hole drops the later run.
    let b = striped4(10);
    let path = tmp("sparse-unsorted");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.write_at(0, b"ABCDE").unwrap();
    f.write_at(95, b"tail").unwrap();
    assert_eq!(f.size().unwrap(), 99);
    let mut buf = [0xEEu8; 10];
    let got = f.read_runs(&[(40, 5), (0, 5)], &mut buf).unwrap();
    assert_eq!(got, 10);
    assert_eq!(&buf[..5], &[0u8; 5], "hole must read as zeros");
    assert_eq!(&buf[5..], b"ABCDE", "data behind the hole must not be dropped");
    b.delete(&path).unwrap();
}

#[test]
fn one_server_fault_surfaces_error_class() {
    // The striped fan-out reaches each child through the vectored
    // write_runs/read_runs entry points, which carry their own fault
    // ops since PR 3.
    let plan = FaultPlan::new(vec![
        FaultRule::once(FaultOp::WriteRuns, 0, ErrorClass::NoSpace),
        FaultRule::once(FaultOp::ReadRuns, 0, ErrorClass::Io),
    ]);
    let children: Vec<Arc<dyn Backend>> = vec![
        Arc::new(LocalBackend::instant()),
        Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone())),
        Arc::new(LocalBackend::instant()),
        Arc::new(LocalBackend::instant()),
    ];
    let b = StripedBackend::new(children, 8).unwrap();
    let path = tmp("fault");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    // The write crosses all four servers; server 1's injected ENOSPC must
    // surface as the whole operation's error class.
    let err = f.write_at(0, &[0u8; 64]).unwrap_err();
    assert_eq!(err.class, ErrorClass::NoSpace);
    // The rule fired once; a retry lands everywhere.
    assert_eq!(f.write_at(0, &[1u8; 64]).unwrap(), 64);
    let mut back = [0u8; 64];
    let err = f.read_at(0, &mut back).unwrap_err();
    assert_eq!(err.class, ErrorClass::Io);
    assert_eq!(f.read_at(0, &mut back).unwrap(), 64);
    assert!(back.iter().all(|&v| v == 1));
    b.delete(&path).unwrap();
}

#[test]
fn all_access_strategies_roundtrip_on_striped() {
    for name in ALL_STRATEGIES {
        let strat: Box<dyn AccessStrategy> = strategy::by_name(name).unwrap();
        let b = striped4(16);
        let path = tmp(&format!("strat-{name}"));
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        f.set_size(4096).unwrap();
        // Scattered, unsorted runs crossing stripe boundaries.
        let runs = [(100u64, 16usize), (900, 8), (40, 4)];
        let data: Vec<u8> = (0..28u8).collect();
        assert_eq!(strat.write(f.as_ref(), &runs, &data).unwrap(), 28, "{name}");
        let mut back = vec![0u8; 28];
        assert_eq!(strat.read(f.as_ref(), &runs, &mut back).unwrap(), 28, "{name}");
        assert_eq!(back, data, "strategy {name} corrupted data");
        b.delete(&path).unwrap();
    }
}

#[test]
fn mapped_region_readonly_rejects_and_rw_persists() {
    let b = striped4(64);
    let path = tmp("map");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.set_size(1024).unwrap();
    {
        let mut m: Box<dyn MappedRegion> = f.map(0, 1024, false).unwrap();
        let err = m.write(0, b"x").unwrap_err();
        assert_eq!(err.class, ErrorClass::ReadOnly);
    }
    {
        let mut m = f.map(60, 200, true).unwrap(); // straddles units 0..4
        m.write(0, &[5u8; 200]).unwrap();
        m.flush().unwrap();
    }
    let mut back = [0u8; 200];
    f.read_at(60, &mut back).unwrap();
    assert_eq!(back, [5u8; 200]);
    b.delete(&path).unwrap();
}

#[test]
fn mapped_flush_retries_after_transient_fault() {
    let plan = FaultPlan::new(vec![FaultRule::once(FaultOp::WriteRuns, 0, ErrorClass::NoSpace)]);
    let children: Vec<Arc<dyn Backend>> = vec![
        Arc::new(FaultBackend::new(LocalBackend::instant(), plan)),
        Arc::new(LocalBackend::instant()),
    ];
    let b = StripedBackend::new(children, 8).unwrap();
    let path = tmp("map-retry");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.set_size(64).unwrap();
    let mut m = f.map(0, 16, true).unwrap();
    m.write(0, b"persist me!!").unwrap();
    // First flush hits the injected fault; the dirty state must survive
    // so the retry actually writes instead of reporting a hollow Ok.
    let err = m.flush().unwrap_err();
    assert_eq!(err.class, ErrorClass::NoSpace);
    m.flush().unwrap();
    let mut back = [0u8; 12];
    f.read_at(0, &mut back).unwrap();
    assert_eq!(&back, b"persist me!!");
    b.delete(&path).unwrap();
}

#[test]
fn striped_over_nfs_children_roundtrip() {
    let b = StripedBackend::nfs(4, 1024, NfsConfig::instant());
    let path = tmp("nfs");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    let data: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
    f.write_at(13, &data).unwrap();
    f.sync().unwrap();
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(13, &mut back).unwrap(), data.len());
    assert_eq!(back, data);
    b.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// The paper's §3.6 scenarios on striped storage, 4 thread ranks
// ----------------------------------------------------------------------

fn open_striped<'c>(c: &'c dyn Comm, path: &str, unit: u64, info: Info) -> File<'c> {
    let backend: Arc<dyn Backend> = Arc::new(StripedBackend::local(4, unit));
    File::open_with_backend(c, path, amode::RDWR | amode::CREATE, info, backend).unwrap()
}

#[test]
fn paper_coll_scenario_on_striped() {
    let path = tmp("coll");
    threads::run(4, |c| {
        let f = open_striped(c, &path, 64, Info::null());
        let buf: Vec<u8> = (0..1024u32).map(|i| (i + c.rank() as u32) as u8).collect();
        let st = f
            .write_at_all((c.rank() * 1024) as i64, buf.as_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        assert_eq!(st.bytes, 1024);
        c.barrier();
        let mut back = vec![0u8; 1024];
        let st = f
            .read_at_all((c.rank() * 1024) as i64, back.as_mut_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        assert_eq!(st.bytes, 1024);
        assert_eq!(back, buf);
        f.close().unwrap();
    });
    cleanup(&path, 4);
}

#[test]
fn paper_async_scenario_on_striped() {
    let path = tmp("async");
    threads::run(4, |c| {
        let f = open_striped(c, &path, 128, Info::null());
        let buf: Vec<u8> = vec![c.rank() as u8; 1024];
        let req = f
            .iwrite_at((c.rank() * 1024) as i64, buf.as_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 1024);
        c.barrier();
        let req = f
            .iread_at((c.rank() * 1024) as i64, vec![0u8; 1024], 0, 1024, &Datatype::BYTE)
            .unwrap();
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, 1024);
        assert_eq!(back, buf);
        f.close().unwrap();
    });
    cleanup(&path, 4);
}

#[test]
fn paper_atomicity_scenario_on_striped() {
    let path = tmp("atomic");
    threads::run(4, |c| {
        let f = open_striped(c, &path, 256, Info::null());
        f.set_atomicity(true).unwrap();
        assert!(f.get_atomicity());
        let buf = vec![c.rank() as u8; 1024];
        f.write_at((c.rank() * 1024) as i64, buf.as_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        c.barrier();
        let mut back = vec![0u8; 1024];
        f.read_at((c.rank() * 1024) as i64, back.as_mut_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        assert_eq!(back, buf);
        f.set_atomicity(false).unwrap();
        f.close().unwrap();
    });
    cleanup(&path, 4);
}

#[test]
fn paper_misc_scenario_on_striped() {
    let path = tmp("misc");
    threads::run(4, |c| {
        let f = open_striped(c, &path, 64, Info::null());
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let buf: Vec<i32> = (0..256).collect();
        f.seek((c.rank() * 256) as i64, jpio::io::seek::SET).unwrap();
        f.write(buf.as_slice(), 0, 256, &Datatype::INT).unwrap();
        assert_eq!(f.get_position().unwrap(), (c.rank() * 256 + 256) as i64);
        f.seek(-256, jpio::io::seek::CUR).unwrap();
        let mut back = vec![0i32; 256];
        f.read(back.as_mut_slice(), 0, 256, &Datatype::INT).unwrap();
        assert_eq!(back, buf);
        c.barrier();
        f.seek(0, jpio::io::seek::END).unwrap();
        assert_eq!(f.get_position().unwrap(), 1024);
        f.close().unwrap();
    });
    cleanup(&path, 4);
}

#[test]
fn independent_strided_access_uses_whole_plan_dispatch() {
    // A noncontiguous (multi-run) independent access on striped storage
    // takes the scheduler's whole-plan path (`prefers_plan_execution`):
    // the striped backend sees the coalesced run list and dispatches one
    // vectored fan-out per server. Correctness must match the strategy
    // staging path bit for bit.
    let path = tmp("planpath");
    threads::run(2, |c| {
        let f = open_striped(c, &path, 32, Info::null());
        let n = c.size();
        let r = c.rank();
        // Rank r owns every n-th 16-byte cell: multi-run plans whose
        // pieces cross stripe units.
        let ft = Datatype::vector(1, 4, 4, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, (n * 16) as i64).unwrap();
        f.set_view((r * 16) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        let k = 256;
        // Value at each slot = its logical int index, so the flat check
        // below can just expect 0..512.
        let mine: Vec<i32> = (0..k).map(|i| (r * 4 + (i / 4) * (n * 4) + i % 4) as i32).collect();
        f.write_at(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
        c.barrier();
        let mut back = vec![0i32; k];
        let st = f.read_at(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
        assert_eq!(st.bytes, k * 4);
        assert_eq!(back, mine);
        f.close().unwrap();
    });
    // Flat interleave check across both ranks.
    let b = striped4(32);
    let f = b.open(&path, OpenOptions::read_only()).unwrap();
    let mut raw = vec![0u8; 2 * 256 * 4];
    assert_eq!(f.read_at(0, &mut raw).unwrap(), raw.len());
    let ints: Vec<i32> =
        raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
    assert_eq!(ints, (0..512).collect::<Vec<_>>());
    cleanup(&path, 4);
    let _ = std::fs::remove_file(StripedBackend::size_meta_path(&path));
}

#[test]
fn striped_hints_end_to_end() {
    let path = tmp("hints");
    let info = Info::from([
        ("jpio_backend", "striped"),
        ("striping_factor", "4"),
        ("striping_unit", "4096"),
    ]);
    {
        let path = &path;
        let info = &info;
        threads::run(2, move |c| {
            let f = File::open(c, path, amode::RDWR | amode::CREATE, info.clone()).unwrap();
            f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
            let r = c.rank();
            let mine = vec![(r + 1) as i32; 2048]; // 8 KiB each: crosses units
            f.write_at((r * 2048) as i64, mine.as_slice(), 0, 2048, &Datatype::INT).unwrap();
            c.barrier();
            let mut all = vec![0i32; 4096];
            f.read_at(0, all.as_mut_slice(), 0, 4096, &Datatype::INT).unwrap();
            assert!(all[..2048].iter().all(|&v| v == 1));
            assert!(all[2048..].iter().all(|&v| v == 2));
            f.close().unwrap();
        });
    }
    // File::delete resolves the same striped backend and removes the
    // stripe objects.
    File::delete(&path, &info).unwrap();
    for s in 0..4 {
        assert!(
            !std::path::Path::new(&StripedBackend::object_path(&path, s, 4)).exists(),
            "stripe object {s} survived delete"
        );
    }
}

// ----------------------------------------------------------------------
// Forked-process ranks on striped storage
// ----------------------------------------------------------------------

#[test]
fn multiprocess_collective_on_striped() {
    let path = tmp("mp-coll");
    process::run_local(4, |c| {
        let backend: Arc<dyn Backend> = Arc::new(StripedBackend::local(4, 32));
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend,
        )
        .unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let mine: Vec<i32> = (0..512).map(|i| (r * 512 + i) as i32).collect();
        f.write_at_all((r * 512) as i64, mine.as_slice(), 0, 512, &Datatype::INT).unwrap();
        c.barrier();
        let n = 512 * c.size();
        let mut all = vec![0i32; n];
        f.read_at_all(0, all.as_mut_slice(), 0, n, &Datatype::INT).unwrap();
        assert_eq!(all, (0..n as i32).collect::<Vec<_>>());
        f.close().unwrap();
    });
    cleanup(&path, 4);
}

#[test]
fn multiprocess_atomic_mode_on_striped() {
    let path = tmp("mp-atomic");
    process::run_local(3, |c| {
        let backend: Arc<dyn Backend> = Arc::new(StripedBackend::local(4, 64));
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend,
        )
        .unwrap();
        f.set_atomicity(true).unwrap();
        let mine = vec![c.rank() as i32 + 10; 2048]; // 8 KiB over 64 B units
        for _ in 0..5 {
            f.write_at(0, mine.as_slice(), 0, 2048, &Datatype::INT).unwrap();
        }
        c.barrier();
        let mut back = vec![0i32; 2048];
        f.read_at(0, back.as_mut_slice(), 0, 2048, &Datatype::INT).unwrap();
        assert!(
            back.windows(2).all(|w| w[0] == w[1]),
            "torn cross-process atomic write on striped storage"
        );
        f.close().unwrap();
    });
    cleanup(&path, 4);
}
