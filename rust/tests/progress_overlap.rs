//! The per-world progress engine: proofs that the MPI-3.1 nonblocking
//! collectives are *truly* asynchronous.
//!
//! The key instrument is a gated storage backend: every positioned
//! read/write blocks on a gate until the test releases it, and counts
//! completions. With the gate closed, `iwrite_all`/`iread_at_all`
//! returning at all proves no storage I/O runs on the caller; releasing
//! the gate and watching the completion counter rise — while no rank
//! re-enters the library — proves the I/O phase finishes entirely in the
//! background before `wait()` is ever called. Plus request-lifecycle
//! regressions (mid-flight `drop(File)`, test-then-wait) and the
//! `jpio_progress_threads = 0` / tiny-staging fallback paths.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use jpio::comm::{process, threads, Comm, Datatype};
use jpio::io::errors::Result as IoResult;
use jpio::io::hints::keys;
use jpio::io::{amode, File, Info};
use jpio::storage::local::LocalBackend;
use jpio::storage::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-progress-{}-{name}", std::process::id())
}

/// A gate every gated storage operation blocks on until released.
#[derive(Default)]
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Local storage whose positioned reads/writes block on `gate` and count
/// completions in `done`.
struct GatedBackend {
    inner: LocalBackend,
    gate: Arc<Gate>,
    done: Arc<AtomicUsize>,
}

struct GatedFile {
    inner: Arc<dyn StorageFile>,
    gate: Arc<Gate>,
    done: Arc<AtomicUsize>,
}

impl Backend for GatedBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> IoResult<Arc<dyn StorageFile>> {
        Ok(Arc::new(GatedFile {
            inner: self.inner.open(path, opts)?,
            gate: self.gate.clone(),
            done: self.done.clone(),
        }))
    }

    fn delete(&self, path: &str) -> IoResult<()> {
        self.inner.delete(path)
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

impl StorageFile for GatedFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> IoResult<usize> {
        self.gate.wait_open();
        let r = self.inner.read_at(offset, buf);
        self.done.fetch_add(1, Ordering::SeqCst);
        r
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> IoResult<usize> {
        self.gate.wait_open();
        let r = self.inner.write_at(offset, buf);
        self.done.fetch_add(1, Ordering::SeqCst);
        r
    }

    fn size(&self) -> IoResult<u64> {
        self.inner.size()
    }

    fn set_size(&self, size: u64) -> IoResult<()> {
        self.inner.set_size(size)
    }

    fn preallocate(&self, size: u64) -> IoResult<()> {
        self.inner.preallocate(size)
    }

    fn sync(&self) -> IoResult<()> {
        self.inner.sync()
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> IoResult<Box<dyn MappedRegion>> {
        self.inner.map(offset, len, writable)
    }

    fn lock_exclusive(&self) -> IoResult<FileLockGuard> {
        self.inner.lock_exclusive()
    }

    fn backend_name(&self) -> &'static str {
        "gated"
    }
}

fn gated() -> (Arc<GatedBackend>, Arc<Gate>, Arc<AtomicUsize>) {
    let gate = Arc::new(Gate::default());
    let done = Arc::new(AtomicUsize::new(0));
    let backend = Arc::new(GatedBackend {
        inner: LocalBackend::instant(),
        gate: gate.clone(),
        done: done.clone(),
    });
    (backend, gate, done)
}

fn poll_until(deadline_s: u64, what: &str, mut ok: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(deadline_s);
    while !ok() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

#[test]
fn iwrite_all_storage_io_completes_in_background_before_wait() {
    let path = tmp("gated-write");
    let (backend, gate, done) = gated();
    threads::run(2, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        let r = c.rank();
        // Block views: rank r's pointer-relative ints land at byte
        // displacement r*512.
        f.set_view((r * 512) as i64, &Datatype::INT, &Datatype::INT, "native", &Info::null())
            .unwrap();
        let mine: Vec<i32> = (0..128).map(|i| (r * 128 + i) as i32).collect();
        // Gate closed: any storage write would block its thread. The call
        // returning at all proves the caller issues no storage I/O.
        let req = f.iwrite_all(mine.as_slice(), 0, 128, &Datatype::INT).unwrap();
        assert_eq!(f.get_position().unwrap(), 128, "pointer advances at the call");
        c.barrier(); // every rank's call returned
        if r == 0 {
            assert_eq!(
                done.load(Ordering::SeqCst),
                0,
                "no storage I/O may complete before the gate opens"
            );
            gate.release();
        }
        c.barrier();
        // The I/O phase finishes on the progress threads while no rank
        // re-enters the library — observable from outside the API.
        poll_until(10, "background write I/O", || done.load(Ordering::SeqCst) >= 1);
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 512);
        c.barrier();
        // Verify through the gated (now open) storage, via a flat view.
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let mut all = vec![0i32; 256];
        f.read_at(0, all.as_mut_slice(), 0, 256, &Datatype::INT).unwrap();
        assert_eq!(all, (0..256).collect::<Vec<_>>());
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn iread_all_aggregation_runs_in_background_before_wait() {
    let path = tmp("gated-read");
    // Pre-populate outside the gate.
    let data: Vec<u8> = (0..=255u8).collect();
    std::fs::write(&path, &data).unwrap();
    let (backend, gate, done) = gated();
    threads::run(2, |c| {
        let f = File::open_with_backend(c, &path, amode::RDONLY, Info::null(), backend.clone())
            .unwrap();
        let r = c.rank();
        // Gate closed: the aggregator read would block its thread — the
        // call still returns immediately.
        let req = f
            .iread_at_all((r * 128) as i64, vec![0u8; 128], 0, 128, &Datatype::BYTE)
            .unwrap();
        c.barrier();
        if r == 0 {
            assert_eq!(
                done.load(Ordering::SeqCst),
                0,
                "no storage read may complete before the gate opens"
            );
            gate.release();
        }
        c.barrier();
        poll_until(10, "background read I/O", || done.load(Ordering::SeqCst) >= 1);
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, 128);
        assert_eq!(&back[..], &data[r * 128..(r + 1) * 128]);
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn requests_survive_mid_flight_file_drop() {
    // The ctx snapshot (Arc'd storage) and the job's world endpoint keep
    // an in-flight nonblocking collective alive after the handle drops.
    let path = tmp("drop");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let r = c.rank();
        let mine = vec![(r + 1) as u8; 64];
        let req = f.iwrite_at_all((r * 64) as i64, mine.as_slice(), 0, 64, &Datatype::BYTE)
            .unwrap();
        drop(f); // mid-flight: the request must complete anyway
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 64);
        c.barrier();

        // Same for a read, with the test-then-wait double-completion
        // pattern on a dropped handle.
        let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
        let mut req = f.iread_at_all(0, vec![0u8; 128], 0, 128, &Datatype::BYTE).unwrap();
        drop(f);
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(res) = req.test() {
                assert!(res.is_ok());
                break;
            }
            assert!(Instant::now() < deadline, "request never completed");
            std::thread::yield_now();
        }
        // wait() after a positive test(): the sanctioned double-completion.
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, 128);
        assert!(back[..64].iter().all(|&v| v == 1));
        assert!(back[64..].iter().all(|&v| v == 2));
        c.barrier();
    });
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(raw.len(), 128);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn progress_threads_hint_disables_the_lane_and_still_round_trips() {
    // jpio_progress_threads = 0 falls back to caller-side exchange (the
    // split collectives' contract); a tiny jpio_staging_buffer_size
    // forces many pipeline rounds on both paths. Data must be identical.
    for (progress, staging) in [("0", "64"), ("1", "64")] {
        let path = tmp(&format!("hint-{progress}-{staging}"));
        threads::run(4, |c| {
            let info = Info::from([
                (keys::PROGRESS_THREADS, progress),
                (keys::STAGING_BUFFER_SIZE, staging),
            ]);
            let f = File::open(c, &path, amode::RDWR | amode::CREATE, info).unwrap();
            let n = c.size();
            let r = c.rank();
            // Strided interleave: the classic two-phase shape.
            let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
            f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
            let k = 256;
            let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
            let req = f.iwrite_all(mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            let (st, ()) = req.wait().unwrap();
            assert_eq!(st.bytes, k * 4);
            c.barrier();
            f.seek(0, jpio::io::seek::SET).unwrap();
            let req = f.iread_all(vec![0i32; k], 0, k, &Datatype::INT).unwrap();
            let (st, back) = req.wait().unwrap();
            assert_eq!(st.bytes, k * 4);
            assert_eq!(back, mine);
            f.close().unwrap();
        });
        let raw = std::fs::read(&path).unwrap();
        let ints: Vec<i32> =
            raw.chunks_exact(4).map(|b| i32::from_le_bytes(b.try_into().unwrap())).collect();
        assert_eq!(ints, (0..ints.len() as i32).collect::<Vec<_>>());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    }
}

#[test]
fn app_thread_collectives_overlap_in_flight_background_collectives() {
    // The tag-band isolation stress: while a nonblocking collective is
    // in flight on the progress threads, the app threads run a blocking
    // collective on the same world. Messages must never cross lanes.
    let path = tmp("lanes");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let nb = vec![(10 + r) as i32; 64];
        let req = f.iwrite_at_all((r * 64) as i64, nb.as_slice(), 0, 64, &Datatype::INT)
            .unwrap();
        // Blocking collective write to a disjoint region while the
        // nonblocking one is (possibly) still exchanging.
        let bl = vec![(20 + r) as i32; 64];
        f.write_at_all((256 + r * 64) as i64, bl.as_slice(), 0, 64, &Datatype::INT).unwrap();
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 256);
        c.barrier();
        let mut all = vec![0i32; 512];
        f.read_at_all(0, all.as_mut_slice(), 0, 512, &Datatype::INT).unwrap();
        for rr in 0..4usize {
            assert!(all[rr * 64..(rr + 1) * 64].iter().all(|&v| v == (10 + rr) as i32));
            assert!(all[256 + rr * 64..256 + (rr + 1) * 64]
                .iter()
                .all(|&v| v == (20 + rr) as i32));
        }
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn off_caller_collectives_across_forked_processes() {
    // The process transport's shared endpoint: the app thread and the
    // progress thread of each forked rank interleave on one socket mesh
    // (bounded-slice recv), across real address spaces.
    let path = tmp("procs");
    process::run_local(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let nb: Vec<i32> = (0..128).map(|i| (r * 128 + i) as i32).collect();
        let req = f.iwrite_at_all((r * 128) as i64, nb.as_slice(), 0, 128, &Datatype::INT)
            .unwrap();
        // App-thread blocking collective while the background one flies.
        let bl = vec![(7 + r) as i32; 32];
        f.write_at_all((256 + r * 32) as i64, bl.as_slice(), 0, 32, &Datatype::INT).unwrap();
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 512);
        c.barrier();
        let req = f.iread_at_all(0, vec![0i32; 320], 0, 320, &Datatype::INT).unwrap();
        let (st, all) = req.wait().unwrap();
        assert_eq!(st.bytes, 320 * 4);
        assert_eq!(&all[..256], &(0..256).collect::<Vec<i32>>()[..]);
        assert!(all[256..288].iter().all(|&v| v == 7));
        assert!(all[288..320].iter().all(|&v| v == 8));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}
