//! Elastic storage membership (DESIGN.md §1c) under fault injection:
//! kill a stripe server, replace it with a blank one, and the rebuild
//! engine must re-materialize its objects from the surviving redundancy
//! — resumable across opens via the `<name>.jpio-rebuild` cursor
//! sidecar, throttled on the maintenance lane, and leaving *zero*
//! degraded-read reconstructions once complete. Live restriping must
//! keep contents byte-identical before/during/after the migration while
//! foreground writes land concurrently, and a randomized schedule of
//! writes/reads/kills/rebuilds must always match a shadow in-memory
//! model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use jpio::comm::{threads, Datatype};
use jpio::io::errors::Result as IoResult;
use jpio::io::{
    amode, AccessOp, Coordination, ErrorClass, File, Info, Positioning, Synchronism,
};
use jpio::storage::faults::{FaultBackend, FaultPlan};
use jpio::storage::layout::{Redundancy, StripeLayout, StripeMap};
use jpio::storage::local::LocalBackend;
use jpio::storage::striped::StripedBackend;
use jpio::storage::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-elastic-{}-{name}", std::process::id())
}

/// A striped backend over `factor` local children where `victim` is
/// wrapped with an (initially empty) fault plan — kill it later with
/// `plan.inject_kill(..)`, replace it with `plan.revive()` plus
/// [`blank_server`].
fn backend_with_victim(
    factor: usize,
    unit: u64,
    redundancy: Redundancy,
    victim: usize,
) -> (StripedBackend, Arc<FaultPlan>) {
    let plan = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..factor)
        .map(|i| {
            if i == victim {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let b = StripedBackend::with_redundancy(children, unit, redundancy).unwrap();
    (b, plan)
}

fn map_of(unit: u64, factor: usize, redundancy: Redundancy) -> StripeMap {
    StripeMap::new(StripeLayout::new(unit, factor).unwrap(), redundancy).unwrap()
}

/// Truncate every stripe object physically hosted on child `victim` —
/// the failed server has been swapped for a healthy *blank* disk. The
/// rotation rule places copy `c` of server `(victim - c) mod factor`
/// on `victim`, so those replica objects blank along with the primary.
fn blank_server(path: &str, victim: usize, factor: usize, redundancy: Redundancy, gen: u64) {
    let mut objects = vec![StripedBackend::object_path_gen(path, victim, factor, gen)];
    if let Redundancy::Replica(k) = redundancy {
        for c in 1..k {
            let src = (victim + factor - (c % factor)) % factor;
            objects.push(StripedBackend::replica_object_path_gen(path, src, factor, c, gen));
        }
    }
    for o in objects {
        std::fs::OpenOptions::new()
            .write(true)
            .create(true)
            .open(&o)
            .unwrap()
            .set_len(0)
            .unwrap();
    }
}

/// Bytes the rebuild engine must re-materialize onto `victim`: its
/// primary object plus every replica copy the rotation hosts there.
fn expected_rebuild_bytes(map: &StripeMap, victim: usize, size: u64) -> u64 {
    let factor = map.layout.factor;
    let mut total = map.child_len(victim, size);
    if let Redundancy::Replica(k) = map.redundancy {
        for c in 1..k {
            let src = (victim + factor - (c % factor)) % factor;
            total += map.child_len(src, size);
        }
    }
    total
}

fn cursor_exists(path: &str) -> bool {
    std::path::Path::new(&StripedBackend::rebuild_cursor_path(path)).exists()
}

// ----------------------------------------------------------------------
// Kill → blank-replace → rebuild → full-redundancy round trip
// ----------------------------------------------------------------------

/// The acceptance scenario: degraded service while the server is dead,
/// then a blank replacement plus `rebuild_now` restores full redundancy
/// — the re-read reconstructs *nothing* (exact `degraded_reads` count)
/// and the rebuilt byte count matches the layout's prescription exactly.
fn kill_blank_rebuild_roundtrip(factor: usize, unit: u64, redundancy: Redundancy, victim: usize) {
    let (b, plan) = backend_with_victim(factor, unit, redundancy, victim);
    let path = tmp(&format!("roundtrip-{}-{victim}", b.name()));
    let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    let data: Vec<u8> = (1..=251u8).cycle().take(777).collect();
    f.write_at(0, &data).unwrap();
    assert!(f.take_advisories().is_empty(), "healthy write must not degrade");

    // Failed-stop: reads still round-trip, via reconstruction.
    plan.inject_kill(ErrorClass::Io);
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(0, &mut back).unwrap(), data.len());
    assert_eq!(back, data, "degraded read must reconstruct victim {victim}");
    let advisories = f.take_advisories();
    assert!(!advisories.is_empty());
    assert!(advisories.iter().all(|a| a.class == ErrorClass::Degraded));
    assert!(f.backend_counters().degraded_reads > 0);
    let health = f.server_health().unwrap();
    assert!(!health[victim], "failed I/O must mark the server dead");

    // Blank replacement: the fault rules clear (new healthy disk behind
    // the same slot) and the victim's objects truncate to nothing.
    plan.revive();
    blank_server(&path, victim, factor, redundancy, 0);

    let rebuilt = f.rebuild_now().unwrap();
    let map = map_of(unit, factor, redundancy);
    assert_eq!(
        rebuilt,
        expected_rebuild_bytes(&map, victim, data.len() as u64),
        "rebuild must re-materialize exactly the victim's hosted bytes"
    );
    assert_eq!(f.backend_counters().rebuild_bytes_reconstructed, rebuilt);
    assert!(!cursor_exists(&path), "completion must remove the cursor sidecar");
    assert_eq!(
        f.server_health().unwrap(),
        vec![true; factor],
        "rebuild completion must restore the target's health"
    );

    // Full-redundancy round trip: zero reconstructions, zero advisories.
    let degraded_before = f.backend_counters().degraded_reads;
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(0, &mut back).unwrap(), data.len());
    assert_eq!(back, data);
    assert_eq!(
        f.backend_counters().degraded_reads,
        degraded_before,
        "post-rebuild reads must hit the rebuilt object, not reconstruct"
    );
    assert!(f.take_advisories().is_empty());
    drop(f);
    b.delete(&path).unwrap();
}

#[test]
fn replica2_kill_blank_rebuild_roundtrip() {
    kill_blank_rebuild_roundtrip(4, 8, Redundancy::Replica(2), 1);
}

#[test]
fn replica3_kill_blank_rebuild_roundtrip() {
    kill_blank_rebuild_roundtrip(4, 8, Redundancy::Replica(3), 2);
}

#[test]
fn parity_kill_blank_rebuild_roundtrip() {
    kill_blank_rebuild_roundtrip(4, 8, Redundancy::Parity, 0);
}

// ----------------------------------------------------------------------
// Second failure mid-rebuild
// ----------------------------------------------------------------------

#[test]
fn second_kill_beyond_parity_tolerance_is_degraded_error() {
    // Parity tolerates one lost server. Blank server 0, rebuild a few
    // rows, then kill survivor 2: the rebuild must stop with a clean
    // Degraded-class error (not corrupt state), keep its cursor for a
    // later resume, and complete once the survivor comes back.
    let plan = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..4)
        .map(|i| {
            if i == 2 {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let b = StripedBackend::with_redundancy(children, 8, Redundancy::Parity).unwrap();
    let path = tmp("second-kill-parity");
    let data: Vec<u8> = (0..=239u8).cycle().take(1500).collect();
    {
        let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &data).unwrap();
    }
    blank_server(&path, 0, 4, Redundancy::Parity, 0);

    let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    let (bytes, done) = f.rebuild_rows(4).unwrap();
    assert!(bytes > 0 && !done, "1500 bytes span more than 4 stripe rows");
    plan.inject_kill(ErrorClass::Io);
    let err = loop {
        match f.rebuild_rows(4) {
            Err(e) => break e,
            Ok((_, true)) => panic!("rebuild must not complete with a dead survivor"),
            Ok(_) => {}
        }
    };
    assert_eq!(err.class, ErrorClass::Degraded);
    assert!(
        err.to_string().contains("loss exceeds the parity tolerance"),
        "unexpected error text: {err}"
    );
    assert!(cursor_exists(&path), "a stalled rebuild must keep its cursor for resume");

    // Survivor replaced/recovered (its data was never lost): the rebuild
    // restarts from the persisted cursor and finishes.
    plan.revive();
    assert!(f.rebuild_now().unwrap() > 0);
    assert!(!cursor_exists(&path));
    let degraded_before = f.backend_counters().degraded_reads;
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(0, &mut back).unwrap(), data.len());
    assert_eq!(back, data);
    assert_eq!(f.backend_counters().degraded_reads, degraded_before);
    drop(f);
    b.delete(&path).unwrap();
}

#[test]
fn second_kill_within_replica3_tolerance_rebuild_completes() {
    // replica:3 tolerates two losses. Blank server 0, kill server 1
    // (which hosts copy 1 of server 0): the rebuild must fall over to
    // copy 2 and still finish everything hosted on the blank server.
    let plan = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..4)
        .map(|i| {
            if i == 1 {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let b = StripedBackend::with_redundancy(children, 8, Redundancy::Replica(3)).unwrap();
    let path = tmp("second-kill-replica3");
    let data: Vec<u8> = (3..=250u8).cycle().take(900).collect();
    {
        let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &data).unwrap();
    }
    blank_server(&path, 0, 4, Redundancy::Replica(3), 0);
    plan.inject_kill(ErrorClass::Io);

    let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    let rebuilt = f.rebuild_now().unwrap();
    let map = map_of(8, 4, Redundancy::Replica(3));
    assert_eq!(
        rebuilt,
        expected_rebuild_bytes(&map, 0, data.len() as u64),
        "a second failure within tolerance must not shrink the rebuild"
    );
    assert!(!cursor_exists(&path));

    plan.revive();
    let degraded_before = f.backend_counters().degraded_reads;
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(0, &mut back).unwrap(), data.len());
    assert_eq!(back, data);
    assert_eq!(f.backend_counters().degraded_reads, degraded_before);
    drop(f);
    b.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// Resumable cursor sidecar
// ----------------------------------------------------------------------

#[test]
fn rebuild_cursor_resumes_across_opens() {
    let children: Vec<Arc<dyn Backend>> =
        (0..4).map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>).collect();
    let b = StripedBackend::with_redundancy(children, 8, Redundancy::Parity).unwrap();
    let path = tmp("resume");
    let data: Vec<u8> = (0..=199u8).cycle().take(2000).collect();
    {
        let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &data).unwrap();
    }
    blank_server(&path, 3, 4, Redundancy::Parity, 0);

    // First session: a few rows, then the handle drops mid-rebuild.
    let bytes_first = {
        let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
        let (bytes, done) = f.rebuild_rows(3).unwrap();
        assert!(!done, "2000 bytes span more than 3 stripe rows");
        bytes
    };
    assert!(cursor_exists(&path), "the cursor sidecar must survive the dropped handle");

    // Second session: the rebuild resumes from the persisted cursor and
    // the two sessions together cover exactly the victim's object.
    let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    let bytes_second = f.rebuild_now().unwrap();
    let map = map_of(8, 4, Redundancy::Parity);
    assert_eq!(
        bytes_first + bytes_second,
        map.child_len(3, data.len() as u64),
        "resume must continue, not restart: no row rebuilt twice"
    );
    assert_eq!(f.backend_counters().rebuild_bytes_reconstructed, bytes_second);
    assert!(!cursor_exists(&path));
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(0, &mut back).unwrap(), data.len());
    assert_eq!(back, data);
    drop(f);
    b.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// The `jpio_rebuild = start` hint: background driver on the maintenance
// lane, surfaced through the File layer and the stats wire record
// ----------------------------------------------------------------------

#[test]
fn rebuild_hint_drives_background_rebuild() {
    let children: Vec<Arc<dyn Backend>> =
        (0..4).map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>).collect();
    let backend: Arc<dyn Backend> =
        Arc::new(StripedBackend::with_redundancy(children, 8, Redundancy::Replica(2)).unwrap());
    let path = tmp("hint-rebuild");
    let data: Vec<u8> = (0..=250u8).cycle().take(1200).collect();
    {
        let f = backend.open(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &data).unwrap();
    }
    blank_server(&path, 1, 4, Redundancy::Replica(2), 0);

    threads::run(1, |c| {
        let info = Info::from([("jpio_rebuild", "start"), ("jpio_rebuild_throttle", "64")]);
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            info,
            backend.clone(),
        )
        .unwrap();
        // The hint persisted a cursor at open and handed the batches to
        // the maintenance lane; wait for the completion signal (cursor
        // removal), then verify full redundancy.
        let deadline = Instant::now() + Duration::from_secs(30);
        while cursor_exists(&path) {
            assert!(Instant::now() < deadline, "background rebuild never completed");
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut back = vec![0u8; data.len()];
        f.read_at(0, back.as_mut_slice(), 0, data.len(), &Datatype::BYTE).unwrap();
        assert_eq!(back, data);
        assert!(f.take_advisories().is_empty(), "healthy post-rebuild reads must not advise");
        // The always-on counters ride the per-file stats record.
        let report = f.stats();
        assert!(report.counter("rebuild_bytes_reconstructed").sum > 0);
        assert_eq!(report.counter("degraded_reconstructed_reads").sum, 0);
        f.close().unwrap();
    });
    backend.delete(&path).unwrap();
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

// ----------------------------------------------------------------------
// Live restriping
// ----------------------------------------------------------------------

#[test]
fn restripe_2_to_4_preserves_contents_under_writes() {
    let path = tmp("restripe-2to4");
    let len = 1000usize;
    let mut want: Vec<u8> = (0..=249u8).cycle().take(len).collect();
    let two: Vec<Arc<dyn Backend>> =
        (0..2).map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>).collect();
    let b2 = StripedBackend::with_redundancy(two, 8, Redundancy::None).unwrap();
    {
        let f = b2.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &want).unwrap();
    }

    // Reopening with a different striping factor starts a migration.
    let four: Vec<Arc<dyn Backend>> =
        (0..4).map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>).collect();
    let b4 = StripedBackend::with_redundancy(four, 8, Redundancy::None).unwrap();
    let f = b4.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    assert!(f.migration_active(), "a changed striping factor must start a migration");

    // Before any step: the router serves everything from the old layout.
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want, "pre-step contents must be byte-identical");

    // One bounded step; the cursor is row-aligned in the new layout.
    let moved = f.migrate_step(64).unwrap();
    assert_eq!(moved, 64, "64 is two new-layout rows, so the step is exact");
    assert!(f.migration_active());

    // A write straddling the cursor routes per byte range: below to the
    // new generation, at-or-above to the old one.
    f.write_at(44, &[0x5Au8; 40]).unwrap();
    want[44..84].fill(0x5A);
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want, "mid-migration contents must be byte-identical");

    f.drive_migration().unwrap();
    assert!(!f.migration_active());
    let dw = 8 * 4;
    assert_eq!(
        f.backend_counters().restripe_rows_migrated,
        (len as u64).div_ceil(dw),
        "every new-layout row must be counted exactly once"
    );
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want, "post-migration contents must be byte-identical");

    // The old generation's objects are retired at finalize.
    for s in 0..2 {
        let object = StripedBackend::object_path(&path, s, 2);
        let remaining = std::fs::metadata(&object).map(|m| m.len()).unwrap_or(0);
        assert_eq!(remaining, 0, "old-generation object {s} must be truncated");
    }

    // A reopen sees the stable new layout — nothing left to migrate.
    drop(f);
    let f = b4.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    assert!(!f.migration_active());
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want);
    drop(f);
    b4.delete(&path).unwrap();
}

#[test]
fn restripe_none_to_parity_enables_reconstruction() {
    let path = tmp("restripe-parity");
    let len = 900usize;
    let mut want: Vec<u8> = (7..=230u8).cycle().take(len).collect();
    let plain_children: Vec<Arc<dyn Backend>> =
        (0..4).map(|_| Arc::new(LocalBackend::instant()) as Arc<dyn Backend>).collect();
    let plain = StripedBackend::with_redundancy(plain_children, 8, Redundancy::None).unwrap();
    {
        let f = plain.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
        f.write_at(0, &want).unwrap();
    }

    // Reopen with `jpio_stripe_redundancy = parity` semantics: same
    // factor, new redundancy — a migration into a parity generation.
    let (bp, plan) = backend_with_victim(4, 8, Redundancy::Parity, 1);
    let f = bp.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();
    assert!(f.migration_active(), "a changed redundancy mode must start a migration");
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want);

    let moved = f.migrate_step(48).unwrap();
    assert_eq!(moved, 48, "48 is two parity data rows, so the step is exact");
    // Straddle the cursor with a foreground write.
    f.write_at(43, &[0xC3u8; 10]).unwrap();
    want[43..53].fill(0xC3);
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want, "mid-migration contents must be byte-identical");

    f.drive_migration().unwrap();
    assert!(!f.migration_active());
    assert_eq!(f.backend_counters().restripe_rows_migrated, (len as u64).div_ceil(24));
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want);

    // The migrated file carries real parity now: kill a server and the
    // contents reconstruct instead of erroring.
    let degraded_before = f.backend_counters().degraded_reads;
    plan.inject_kill(ErrorClass::Io);
    let mut back = vec![0u8; len];
    assert_eq!(f.read_at(0, &mut back).unwrap(), len);
    assert_eq!(back, want, "the new parity generation must reconstruct the dead server");
    assert!(f.backend_counters().degraded_reads > degraded_before);
    let advisories = f.take_advisories();
    assert!(!advisories.is_empty());
    assert!(advisories.iter().all(|a| a.class == ErrorClass::Degraded));
    plan.revive();
    drop(f);
    bp.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// Randomized schedule vs a shadow in-memory model
// ----------------------------------------------------------------------

/// SplitMix64 — deterministic, dependency-free, seed-printable.
struct Rng(u64);

impl Rng {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

#[test]
fn randomized_schedule_matches_shadow_model() {
    // Reproduce a failure with JPIO_ELASTIC_SEED=<printed seed>.
    let seed = std::env::var("JPIO_ELASTIC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x6A70_696F_2D65_6C61);
    println!("elastic membership property schedule: JPIO_ELASTIC_SEED={seed}");
    run_schedule(seed);
    run_schedule(seed ^ 0x5DEE_CE66);
}

fn run_schedule(seed: u64) {
    let mut rng = Rng(seed);
    let factor = 4usize;
    let unit = 8u64;
    let redundancy = Redundancy::Replica(2);
    let victim = rng.below(factor as u64) as usize;
    let (b, plan) = backend_with_victim(factor, unit, redundancy, victim);
    let path = tmp(&format!("prop-{seed:016x}"));
    let f = b.open_striped_manual(&path, OpenOptions::rw_create()).unwrap();

    const SPAN: u64 = 2048;
    let mut shadow: Vec<u8> = Vec::new();
    let mut killed = false;
    let mut fill = 1u8;
    let mut advisories = 0u64;

    for step in 0..240 {
        match rng.below(100) {
            0..=44 => {
                let off = rng.below(SPAN);
                let len = 1 + rng.below(96) as usize;
                let mut data = vec![0u8; len];
                for byte in &mut data {
                    *byte = fill;
                    fill = fill.wrapping_add(1).max(1);
                }
                f.write_at(off, &data)
                    .unwrap_or_else(|e| panic!("seed {seed:#x} step {step}: write failed: {e}"));
                let end = off as usize + len;
                if shadow.len() < end {
                    shadow.resize(end, 0);
                }
                shadow[off as usize..end].copy_from_slice(&data);
            }
            45..=79 => {
                let off = rng.below(SPAN + 64);
                let len = 1 + rng.below(160) as usize;
                let mut back = vec![0xEEu8; len];
                let got = f
                    .read_at(off, &mut back)
                    .unwrap_or_else(|e| panic!("seed {seed:#x} step {step}: read failed: {e}"));
                let want = shadow.len().saturating_sub(off as usize).min(len);
                assert_eq!(got, want, "seed {seed:#x} step {step}: EOF clamp at offset {off}");
                if got > 0 {
                    assert_eq!(
                        &back[..got],
                        &shadow[off as usize..off as usize + got],
                        "seed {seed:#x} step {step}: contents diverge at offset {off}"
                    );
                }
            }
            80..=89 if !killed => {
                plan.inject_kill(ErrorClass::Io);
                killed = true;
            }
            90..=99 if killed => {
                // Blank replacement + rebuild restores full redundancy.
                plan.revive();
                blank_server(&path, victim, factor, redundancy, 0);
                let rebuilt = f.rebuild_now().unwrap_or_else(|e| {
                    panic!("seed {seed:#x} step {step}: rebuild failed: {e}")
                });
                let map = map_of(unit, factor, redundancy);
                assert_eq!(
                    rebuilt,
                    expected_rebuild_bytes(&map, victim, shadow.len() as u64),
                    "seed {seed:#x} step {step}: rebuild must cover exactly the hosted bytes"
                );
                assert_eq!(f.server_health().unwrap(), vec![true; factor]);
                killed = false;
            }
            _ => {}
        }
        // Drain advisories every step: none may be lost or misclassified.
        for a in f.take_advisories() {
            assert_eq!(a.class, ErrorClass::Degraded, "seed {seed:#x} step {step}: {a}");
            advisories += 1;
        }
    }

    if killed {
        plan.revive();
        blank_server(&path, victim, factor, redundancy, 0);
        f.rebuild_now().unwrap();
        for a in f.take_advisories() {
            assert_eq!(a.class, ErrorClass::Degraded);
            advisories += 1;
        }
    }
    let mut back = vec![0u8; shadow.len()];
    if !shadow.is_empty() {
        assert_eq!(f.read_at(0, &mut back).unwrap(), shadow.len());
    }
    assert_eq!(back, shadow, "seed {seed:#x}: final contents diverge from the shadow model");
    let counters = f.backend_counters();
    assert!(
        advisories >= counters.degraded_reads,
        "seed {seed:#x}: {} degraded reads but only {advisories} advisories drained",
        counters.degraded_reads
    );
    drop(f);
    b.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// Carry-over regressions
// ----------------------------------------------------------------------

#[test]
fn mapped_region_buffered_emulation_survives_dead_server() {
    // The striped MappedRegion is a buffered emulation: prefill on
    // creation, dirty-range write-back on flush. Both halves must run
    // degraded (reconstruct / tolerated write failure) under a killed
    // server instead of erroring or corrupting the gap bytes.
    let (b, plan) = backend_with_victim(4, 8, Redundancy::Parity, 2);
    let path = tmp("map-degraded");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    let data: Vec<u8> = (0..=255u8).cycle().take(256).collect();
    f.write_at(0, &data).unwrap();
    plan.inject_kill(ErrorClass::Io);

    let mut region = f.map(16, 64, true).unwrap();
    let mut got = vec![0u8; 64];
    region.read(0, &mut got).unwrap();
    assert_eq!(got, &data[16..80], "map prefill must reconstruct the dead server's units");
    region.write(8, &[0xABu8; 16]).unwrap();
    region.flush().unwrap();
    drop(region);

    let mut want = data.clone();
    want[24..40].fill(0xAB);
    let mut back = vec![0u8; data.len()];
    assert_eq!(f.read_at(0, &mut back).unwrap(), data.len());
    assert_eq!(back, want, "mapped write-back must preserve gap bytes while degraded");
    let advisories = f.take_advisories();
    assert!(!advisories.is_empty());
    assert!(advisories.iter().all(|a| a.class == ErrorClass::Degraded));
    b.delete(&path).unwrap();
}

/// A child backend that counts the bytes of every write dispatched to
/// it — proof that an operation reached the striped per-server fan-out.
struct CountingBackend {
    inner: LocalBackend,
    write_bytes: Arc<AtomicU64>,
}

struct CountingFile {
    inner: Arc<dyn StorageFile>,
    write_bytes: Arc<AtomicU64>,
}

impl Backend for CountingBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> IoResult<Arc<dyn StorageFile>> {
        Ok(Arc::new(CountingFile {
            inner: self.inner.open(path, opts)?,
            write_bytes: self.write_bytes.clone(),
        }))
    }

    fn delete(&self, path: &str) -> IoResult<()> {
        self.inner.delete(path)
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

impl StorageFile for CountingFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> IoResult<usize> {
        self.inner.read_at(offset, buf)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> IoResult<usize> {
        self.write_bytes.fetch_add(buf.len() as u64, Ordering::SeqCst);
        self.inner.write_at(offset, buf)
    }

    fn write_runs(&self, runs: &[(u64, usize)], buf: &[u8]) -> IoResult<usize> {
        self.write_bytes.fetch_add(buf.len() as u64, Ordering::SeqCst);
        self.inner.write_runs(runs, buf)
    }

    fn size(&self) -> IoResult<u64> {
        self.inner.size()
    }

    fn set_size(&self, size: u64) -> IoResult<()> {
        self.inner.set_size(size)
    }

    fn preallocate(&self, size: u64) -> IoResult<()> {
        self.inner.preallocate(size)
    }

    fn sync(&self) -> IoResult<()> {
        self.inner.sync()
    }

    fn map(
        &self,
        offset: u64,
        len: usize,
        writable: bool,
    ) -> IoResult<Box<dyn MappedRegion>> {
        self.inner.map(offset, len, writable)
    }

    fn lock_exclusive(&self) -> IoResult<FileLockGuard> {
        self.inner.lock_exclusive()
    }

    fn backend_name(&self) -> &'static str {
        "counting"
    }
}

#[test]
fn per_op_hint_overlay_reaches_striped_fanout() {
    // Regression: a per-op `jpio_cache = disable` overlay must carry the
    // submission past the page cache and synchronously onto the striped
    // backend's per-server fan-out — the counting children see the bytes
    // before the submission returns.
    let write_bytes = Arc::new(AtomicU64::new(0));
    let children: Vec<Arc<dyn Backend>> = (0..4)
        .map(|_| {
            Arc::new(CountingBackend {
                inner: LocalBackend::instant(),
                write_bytes: write_bytes.clone(),
            }) as Arc<dyn Backend>
        })
        .collect();
    let backend: Arc<dyn Backend> =
        Arc::new(StripedBackend::with_redundancy(children, 8, Redundancy::None).unwrap());
    let path = tmp("overlay-fanout");
    threads::run(1, |c| {
        let info = Info::from([("jpio_cache", "enable")]);
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            info,
            backend.clone(),
        )
        .unwrap();
        let data: Vec<u8> = (0..96u8).collect();
        let bypass = Info::from([("jpio_cache", "disable")]);
        let before = write_bytes.load(Ordering::SeqCst);
        let wop = AccessOp::write(
            Positioning::Explicit(0),
            Coordination::Independent,
            Synchronism::Blocking,
            0,
            data.len(),
            &Datatype::BYTE,
        );
        f.submit_write_with(&wop, data.as_slice(), Some(&bypass)).unwrap();
        let after = write_bytes.load(Ordering::SeqCst);
        assert!(
            after >= before + data.len() as u64,
            "overlay write must land synchronously on the fan-out ({before} -> {after})"
        );
        let report = f.stats();
        let cached = ["cache_hit_bytes", "cache_miss_bytes", "write_behind_flush_bytes"]
            .iter()
            .map(|&k| report.counter(k).sum)
            .sum::<u64>();
        assert_eq!(cached, 0, "the bypassed submission must never enter the page cache");
        // The bytes are already on the stripes: a bypass read returns them.
        let mut back = vec![0u8; data.len()];
        let rop = AccessOp::read(
            Positioning::Explicit(0),
            Coordination::Independent,
            Synchronism::Blocking,
            0,
            data.len(),
            &Datatype::BYTE,
        );
        f.submit_read_with(&rop, back.as_mut_slice(), Some(&bypass)).unwrap();
        assert_eq!(back, data);
        f.close().unwrap();
    });
    backend.delete(&path).unwrap();
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}
