//! Darshan-style instrumentation: exact per-op counting through the
//! `AccessOp` choke point, close-time collective reduction agreement
//! across forked processes, and the JSONL trace stream round-tripping
//! through its reference decoder.

use jpio::comm::{process, threads, Comm, Datatype};
use jpio::io::hints::keys;
use jpio::io::{amode, File, Info, Reduced, TraceEvent};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-stats-test-{}-{name}", std::process::id())
}

/// Every counter of a known three-op workload — one independent
/// explicit-offset write, one nonblocking independent write + wait, one
/// collective strided read — counted exactly, then reduced across the
/// 2-rank world at close.
#[test]
fn exact_counts_reduce_across_ranks() {
    let path = tmp("exact.dat");
    threads::run(2, |c| {
        let f = File::open(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::from([(keys::STATS, "true")]),
        )
        .unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let k = 8usize;

        // Op 1: independent blocking write of this rank's block — file
        // ints [r*8, r*8+8) hold their global index.
        let mine: Vec<i32> = (0..k).map(|i| (r * k + i) as i32).collect();
        f.write_at((r * k) as i64, mine.as_slice(), 0, k, &Datatype::INT).unwrap();

        // Op 2: nonblocking independent write of a second block at
        // [16 + r*8, ...), completed with a wait.
        f.iwrite_at(((2 + r) * k) as i64, mine.as_slice(), 0, k, &Datatype::INT)
            .unwrap()
            .wait()
            .unwrap();
        c.barrier();

        // Op 3: collective strided read — a vector view combing the
        // first 16 ints: rank 0 the even slots, rank 1 the odd ones.
        let ft = Datatype::vector(k, 1, 2, &Datatype::INT).unwrap();
        f.set_view(4 * r as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        let mut comb = vec![0i32; k];
        f.read_at_all(0, comb.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
        for (j, &v) in comb.iter().enumerate() {
            assert_eq!(v as usize, 2 * j + r, "strided read must comb the file");
        }

        // Close runs the collective min/max/sum reduction; the report is
        // then identical on every rank.
        f.close().unwrap();
        let report = f.stats();
        assert_eq!(report.ranks, 2);

        // Per rank: 2 writes + 1 read, 2 independent + 1 collective,
        // 2 blocking + 1 nonblocking, 3 explicit-offset; each op moved
        // 8 ints = 32 bytes.
        let per = |n: u64| Reduced { min: n, max: n, sum: 2 * n };
        assert_eq!(report.counter("write_ops"), per(2));
        assert_eq!(report.counter("read_ops"), per(1));
        assert_eq!(report.counter("independent_ops"), per(2));
        assert_eq!(report.counter("collective_ops"), per(1));
        assert_eq!(report.counter("blocking_ops"), per(2));
        assert_eq!(report.counter("nonblocking_ops"), per(1));
        assert_eq!(report.counter("explicit_offset_ops"), per(3));
        assert_eq!(report.counter("split_ops"), per(0));
        assert_eq!(report.counter("shared_ptr_ops"), per(0));
        assert_eq!(report.counter("bytes_requested"), per(96));
        assert_eq!(report.counter("bytes_moved"), per(96));
        // Run shapes: the two contiguous writes compile 1-run plans, the
        // vector read an 8-run comb.
        assert_eq!(report.counter("contiguous_plans"), per(2));
        assert_eq!(report.counter("strided_plans"), per(1));
        assert_eq!(report.counter("plan_runs"), per(10));
        // Only strided lookups consult the plan cache: one fresh compile.
        assert_eq!(report.counter("plan_cache_misses"), per(1));
        assert_eq!(report.counter("plan_cache_hits"), per(0));
        assert_eq!(report.counter("datarep_converted_ops"), per(0));

        // Phase timers were on: every pipeline stage this workload
        // crosses must have recorded spans.
        assert!(report.phase("validate").samples.sum >= 6, "3 submissions per rank");
        assert!(report.phase("resolve").samples.sum >= 6);
        assert!(report.phase("storage").samples.sum >= 2);
        assert!(report.phase("wait").samples.sum >= 2, "one wait per rank");
        assert!(report.phase("exchange").samples.sum >= 2, "collective read exchanges");

        // The render shows per-phase timing and the byte counters.
        let text = report.render();
        assert!(text.contains("2 ranks"));
        assert!(text.contains("bytes_moved"));
        assert!(text.contains("storage"));
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

/// Counters stay on with the hint off, while the phase timers stay
/// entirely silent (no samples anywhere).
#[test]
fn hint_off_counts_without_timers() {
    let path = tmp("off.dat");
    threads::run(1, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let data = [7i32; 4];
        f.write_at(0, &data[..], 0, 4, &Datatype::INT).unwrap();
        let report = f.stats();
        assert_eq!(report.ranks, 1, "hint off: local snapshot, no reduction");
        assert_eq!(report.counter("write_ops").sum, 1);
        assert_eq!(report.counter("bytes_requested").sum, 16);
        for (name, p) in report.phases() {
            assert_eq!(p.samples.sum, 0, "phase {name} must record nothing with the hint off");
        }
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

/// The close-time reduction must agree across *forked processes*: every
/// rank allgathers its rendered report and asserts byte-identical text,
/// plus exact reduced values for a known one-op-per-rank workload.
#[test]
fn forked_ranks_agree_on_reduced_report() {
    let path = tmp("procs.dat");
    process::run_local(4, |c| {
        let f = File::open(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::from([(keys::STATS, "enable")]),
        )
        .unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let mine: Vec<i32> = (0..64).map(|i| (r * 64 + i) as i32).collect();
        f.write_at_all((r * 64) as i64, mine.as_slice(), 0, 64, &Datatype::INT).unwrap();
        f.close().unwrap();
        let report = f.stats();
        assert_eq!(report.ranks, 4);
        assert_eq!(report.counter("write_ops"), Reduced { min: 1, max: 1, sum: 4 });
        assert_eq!(report.counter("collective_ops"), Reduced { min: 1, max: 1, sum: 4 });
        assert_eq!(report.counter("bytes_requested"), Reduced { min: 256, max: 256, sum: 1024 });
        // Byte-identical rendering on every rank — the shared-file
        // record really is shared.
        let texts = c.allgather(report.render().as_bytes());
        for t in &texts {
            assert_eq!(t, &texts[0], "all ranks must hold the identical reduced report");
        }
    });
    File::delete(&path, &Info::null()).unwrap();
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

/// The `jpio_stats_trace` JSONL stream round-trips through the schema's
/// reference decoder: every emitted line parses, re-encodes to the same
/// bytes, and carries the expected op/phase vocabulary.
#[test]
fn trace_stream_round_trips_through_schema() {
    let path = tmp("trace.dat");
    let trace = tmp("trace.jsonl");
    threads::run(1, |c| {
        let f = File::open(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::from([(keys::STATS, "true"), (keys::STATS_TRACE, trace.as_str())]),
        )
        .unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let data: Vec<i32> = (0..16).collect();
        f.write_at(0, data.as_slice(), 0, 16, &Datatype::INT).unwrap();
        let mut back = vec![0i32; 16];
        f.read_at(0, back.as_mut_slice(), 0, 16, &Datatype::INT).unwrap();
        assert_eq!(back, data);
        f.close().unwrap();
    });
    let stream = std::fs::read_to_string(format!("{trace}.0")).expect("per-rank trace file");
    let events: Vec<TraceEvent> = stream
        .lines()
        .map(|line| {
            let ev = TraceEvent::parse(line).expect("every trace line parses");
            assert_eq!(ev.to_json(), line, "canonical encode must round-trip");
            ev
        })
        .collect();
    assert!(!events.is_empty());
    assert!(events.iter().all(|e| e.rank == 0));
    assert!(
        events.iter().any(|e| e.kind == "op" && e.name == "write_at" && e.bytes == 64),
        "the independent write must appear as an op event"
    );
    assert!(
        events.iter().any(|e| e.kind == "op" && e.name == "read_at"),
        "the independent read must appear as an op event"
    );
    assert!(
        events.iter().any(|e| e.kind == "phase" && e.name == "storage"),
        "storage phase spans must appear"
    );
    assert!(
        events.iter().any(|e| e.kind == "phase" && e.name == "validate"),
        "validate phase spans must appear"
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    let _ = std::fs::remove_file(format!("{trace}.0"));
}
