//! The structured dataset layer end to end: collective
//! `put_vara`/`get_vara` across forked processes on striped storage,
//! the `external32` on-disk encoding, cache-on/cache-off byte equality,
//! degraded reads with a killed parity server, writer→reader header
//! coherence through `sync`, and the golden-fixture container-format
//! drift check.

use std::sync::Arc;

use jpio::comm::{process, threads, Comm, Datatype};
use jpio::dataset::header::{Header, UNLIMITED};
use jpio::dataset::Dataset;
use jpio::io::{amode, ErrorClass, File, Info};
use jpio::storage::faults::{FaultBackend, FaultPlan};
use jpio::storage::layout::Redundancy;
use jpio::storage::local::LocalBackend;
use jpio::storage::striped::StripedBackend;
use jpio::storage::Backend;

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-dsround-{}-{name}.jpds", std::process::id())
}

fn cleanup(path: &str) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
}

// ----------------------------------------------------------------------
// Acceptance: 4 forked ranks, striped storage, 2-D block decomposition
// ----------------------------------------------------------------------

/// The PR's acceptance scenario: four *processes* (the distributed-memory
/// configuration) collectively write a 16×16 variable block-decomposed
/// 2×2, and every rank reads the whole variable back byte-identically —
/// over striped storage resolved from the ROMIO striping hints.
#[test]
fn four_process_block_decomposed_roundtrip_on_striped_storage() {
    let path = tmp("procs");
    let info = Info::from([
        ("jpio_backend", "striped"),
        ("striping_factor", "4"),
        ("striping_unit", "4096"),
    ]);
    {
        let path = &path;
        let info = &info;
        process::run_local(4, move |c| {
            let f = File::open(c, path, amode::RDWR | amode::CREATE, info.clone()).unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", 16).unwrap();
            let y = ds.def_dim("y", 16).unwrap();
            let grid = ds.def_var("grid", &Datatype::INT, "native", &[x, y]).unwrap();
            ds.enddef().unwrap();
            let r = c.rank();
            let (starts, subs) = Datatype::block_decompose(&[16, 16], &[2, 2], r).unwrap();
            let n = subs[0] * subs[1];
            let mine: Vec<i32> = (0..n).map(|i| (r * 1000 + i) as i32).collect();
            ds.put_vara(grid, &starts, &subs, mine.as_slice()).unwrap();
            // Own block back first…
            let mut back = vec![0i32; n];
            ds.get_vara(grid, &starts, &subs, back.as_mut_slice()).unwrap();
            assert_eq!(back, mine, "rank {r}: own block");
            // …then the whole variable, against every rank's block.
            let mut all = vec![0i32; 256];
            ds.get_vara(grid, &[0, 0], &[16, 16], all.as_mut_slice()).unwrap();
            let mut expect = vec![0i32; 256];
            for o in 0..4usize {
                let (s, sub) = Datatype::block_decompose(&[16, 16], &[2, 2], o).unwrap();
                for li in 0..sub[0] {
                    for lj in 0..sub[1] {
                        expect[(s[0] + li) * 16 + s[1] + lj] = (o * 1000 + li * sub[1] + lj) as i32;
                    }
                }
            }
            assert_eq!(all, expect, "rank {r}: full variable");
            ds.close().unwrap();
        });
    }
    File::delete(&path, &info).unwrap();
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

// ----------------------------------------------------------------------
// external32: canonical big-endian bytes on disk
// ----------------------------------------------------------------------

#[test]
fn external32_variables_are_big_endian_on_disk() {
    let path = tmp("ext32");
    threads::run(1, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let ds = Dataset::create(f).unwrap();
        let x = ds.def_dim("x", 5).unwrap();
        let vi = ds.def_var("vi", &Datatype::INT, "external32", &[x]).unwrap();
        let vd = ds.def_var("vd", &Datatype::DOUBLE, "external32", &[x]).unwrap();
        ds.enddef().unwrap();
        let ints: Vec<i32> = (0..5).map(|i| i * 3 - 7).collect();
        let dbls: Vec<f64> = (0..5).map(|i| i as f64 * 1.5 - 2.25).collect();
        ds.put_vara(vi, &[0], &[5], ints.as_slice()).unwrap();
        ds.put_vara(vd, &[0], &[5], dbls.as_slice()).unwrap();
        // Decode-on-read returns the native values…
        let mut bi = vec![0i32; 5];
        ds.get_vara(vi, &[0], &[5], bi.as_mut_slice()).unwrap();
        assert_eq!(bi, ints);
        let mut bd = vec![0f64; 5];
        ds.get_vara(vd, &[0], &[5], bd.as_mut_slice()).unwrap();
        assert_eq!(bd, dbls);
        ds.close().unwrap();
    });
    // …while the raw file bytes are canonical big-endian at each
    // variable's header-declared offset.
    let raw = std::fs::read(&path).unwrap();
    let hdr = Header::decode(&raw).unwrap();
    let vi = hdr.vars.iter().find(|v| v.name == "vi").unwrap();
    let vd = hdr.vars.iter().find(|v| v.name == "vd").unwrap();
    assert!(vi.external32 && vd.external32);
    let want_i: Vec<u8> = (0..5i32).flat_map(|i| (i * 3 - 7).to_be_bytes()).collect();
    let at = vi.data_offset as usize;
    assert_eq!(&raw[at..at + 20], &want_i[..], "INT external32 bytes");
    let want_d: Vec<u8> =
        (0..5).flat_map(|i| (i as f64 * 1.5 - 2.25).to_be_bytes()).collect();
    let at = vd.data_offset as usize;
    assert_eq!(&raw[at..at + 40], &want_d[..], "DOUBLE external32 bytes");
    cleanup(&path);
}

// ----------------------------------------------------------------------
// Page cache on/off: identical bytes either way
// ----------------------------------------------------------------------

#[test]
fn cached_and_uncached_handles_produce_identical_files() {
    let cached = tmp("cache-on");
    let uncached = tmp("cache-off");
    {
        let cached = &cached;
        let uncached = &uncached;
        threads::run(2, move |c| {
            let infos = [Info::from([("jpio_cache", "enable")]), Info::null()];
            for (path, info) in [cached, uncached].into_iter().zip(infos) {
                let f = File::open(c, path, amode::RDWR | amode::CREATE, info).unwrap();
                let ds = Dataset::create(f).unwrap();
                let x = ds.def_dim("x", 8).unwrap();
                let y = ds.def_dim("y", 4).unwrap();
                let v = ds.def_var("v", &Datatype::LONG, "native", &[x, y]).unwrap();
                ds.put_att("title", b"cache parity").unwrap();
                ds.enddef().unwrap();
                let r = c.rank();
                let mine: Vec<i64> = (0..16).map(|i| (r * 1000 + i) as i64).collect();
                ds.put_vara(v, &[r * 4, 0], &[4, 4], mine.as_slice()).unwrap();
                ds.close().unwrap();
            }
        });
    }
    let a = std::fs::read(&cached).unwrap();
    let b = std::fs::read(&uncached).unwrap();
    assert_eq!(a, b, "cache write-behind must not change the bytes on disk");
    cleanup(&cached);
    cleanup(&uncached);
}

// ----------------------------------------------------------------------
// Degraded reads: dataset access over parity stripes with a dead server
// ----------------------------------------------------------------------

#[test]
fn degraded_parity_read_surfaces_advisories_through_dataset() {
    let plan = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..4)
        .map(|i| {
            if i == 1 {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let striped = StripedBackend::with_redundancy(children, 8, Redundancy::Parity).unwrap();
    let backend: Arc<dyn Backend> = Arc::new(striped);
    let path = tmp("degraded");
    let advisory_counts = {
        let path = &path;
        let backend = &backend;
        let plan = &plan;
        threads::run(4, move |c| {
            let f = File::open_with_backend(
                c,
                path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend.clone(),
            )
            .unwrap();
            let ds = Dataset::create(f).unwrap();
            let x = ds.def_dim("x", 8).unwrap();
            let y = ds.def_dim("y", 8).unwrap();
            let v = ds.def_var("v", &Datatype::INT, "native", &[x, y]).unwrap();
            ds.enddef().unwrap();
            let r = c.rank();
            let mine: Vec<i32> = (0..16).map(|i| (r * 100 + i) as i32).collect();
            ds.put_vara(v, &[r * 2, 0], &[2, 8], mine.as_slice()).unwrap();
            // Kill one stripe server once everything is on disk.
            c.barrier();
            if r == 0 {
                plan.inject_kill(ErrorClass::Io);
            }
            c.barrier();
            let _ = ds.file().take_advisories();
            let mut all = vec![0i32; 64];
            ds.get_vara(v, &[0, 0], &[8, 8], all.as_mut_slice()).unwrap();
            for o in 0..4usize {
                let row = &all[o * 16..(o + 1) * 16];
                let expect: Vec<i32> = (0..16).map(|i| (o * 100 + i) as i32).collect();
                assert_eq!(row, &expect[..], "rank {r}: rows of rank {o} after server death");
            }
            let advisories = ds.file().take_advisories();
            for a in &advisories {
                assert_eq!(a.class, ErrorClass::Degraded, "rank {r}: {a}");
                assert!(a.to_string().contains("JPIO_ERR_DEGRADED"), "rank {r}: {a}");
            }
            ds.close().unwrap();
            advisories.len()
        })
    };
    assert!(
        advisory_counts.iter().sum::<usize>() > 0,
        "some aggregator must report the degraded parity read"
    );
    let _ = backend.delete(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

// ----------------------------------------------------------------------
// Writer → reader header coherence through sync
// ----------------------------------------------------------------------

#[test]
fn reader_dataset_observes_appended_records_after_sync() {
    let path = tmp("coherence");
    threads::run(2, |c| {
        let fw = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let ds_w = Dataset::create(fw).unwrap();
        let t = ds_w.def_dim("time", UNLIMITED).unwrap();
        let v = ds_w.def_var("v", &Datatype::DOUBLE, "native", &[t]).unwrap();
        ds_w.enddef().unwrap();
        // A second, read-only dataset handle on the same container.
        let fr = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
        let ds_r = Dataset::open(fr).unwrap();
        assert_eq!(ds_r.num_records(), 0);
        let r = c.rank();
        for round in 0..2usize {
            let rec = [(round * 10 + r) as f64];
            ds_w.append_records(v, rec.as_slice()).unwrap();
        }
        assert_eq!(ds_w.num_records(), 4);
        // Writer-sync … reader-sync: the MPI coherence recipe, at the
        // dataset level. The reader then sees all four records.
        ds_w.sync().unwrap();
        ds_r.sync().unwrap();
        assert_eq!(ds_r.num_records(), 4);
        let vr = ds_r.find_var("v").unwrap();
        let mut got = vec![0f64; 4];
        ds_r.get_vara(vr, &[0], &[4], got.as_mut_slice()).unwrap();
        assert_eq!(got, vec![0.0, 1.0, 10.0, 11.0]);
        ds_r.close().unwrap();
        ds_w.close().unwrap();
    });
    cleanup(&path);
}

// ----------------------------------------------------------------------
// Golden fixture: the v1 container format must never drift
// ----------------------------------------------------------------------

/// Committed by the PR that introduced the format (see
/// `tests/fixtures/gen_dataset_v1.py`): a complete v1 container with a
/// record variable, an `external32` fixed variable and attributes.
static FIXTURE: &[u8] = include_bytes!("fixtures/dataset_v1.jpds");

#[test]
fn golden_fixture_header_decodes_and_reencodes_byte_identically() {
    let total = Header::total_bytes(&FIXTURE[..16]).unwrap();
    let hdr = Header::decode(&FIXTURE[..total]).unwrap();
    // Byte-identical re-encode: any codec change that breaks this is a
    // format break and needs a version bump, not a fixture update.
    assert_eq!(hdr.encode(), &FIXTURE[..total], "v1 header format drifted");
    assert_eq!(hdr.num_recs, 2);
    assert_eq!(hdr.dims.len(), 3);
    assert_eq!(hdr.dims[0].len, UNLIMITED);
    let grid = hdr.vars.iter().find(|v| v.name == "grid").unwrap();
    assert!(grid.external32);
}

#[test]
fn golden_fixture_opens_and_reads_known_values() {
    let path = tmp("golden");
    std::fs::write(&path, FIXTURE).unwrap();
    threads::run(1, |c| {
        let f = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
        let ds = Dataset::open(f).unwrap();
        assert_eq!(ds.num_records(), 2);
        assert_eq!(ds.get_att("title").unwrap(), b"golden fixture");
        let grid = ds.find_var("grid").unwrap();
        assert_eq!(ds.get_var_att(grid, "units").unwrap(), b"K");
        let mut g = vec![0i32; 6];
        ds.get_vara(grid, &[0, 0], &[2, 3], g.as_mut_slice()).unwrap();
        assert_eq!(g, vec![1, 2, 3, 4, 5, 6]);
        let t = ds.find_var("t").unwrap();
        let mut series = vec![0f64; 2];
        ds.get_vara(t, &[0], &[2], series.as_mut_slice()).unwrap();
        assert_eq!(series, vec![10.5, 11.5]);
        ds.close().unwrap();
    });
    cleanup(&path);
}
