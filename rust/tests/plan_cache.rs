//! Plan caching at the scheduler: a repeated same-shape access must skip
//! run recompilation (plan-cache hit) while still performing the storage
//! I/O — proven with a counting backend that tallies every positioned
//! read/write reaching storage.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use jpio::comm::{threads, Datatype};
use jpio::io::errors::Result as IoResult;
use jpio::io::{amode, File, Info, PlanCacheStats};
use jpio::storage::local::LocalBackend;
use jpio::storage::{Backend, FileLockGuard, MappedRegion, OpenOptions, StorageFile};

struct CountingBackend {
    inner: LocalBackend,
    reads: Arc<AtomicUsize>,
    writes: Arc<AtomicUsize>,
}

struct CountingFile {
    inner: Arc<dyn StorageFile>,
    reads: Arc<AtomicUsize>,
    writes: Arc<AtomicUsize>,
}

impl Backend for CountingBackend {
    fn open(&self, path: &str, opts: OpenOptions) -> IoResult<Arc<dyn StorageFile>> {
        Ok(Arc::new(CountingFile {
            inner: self.inner.open(path, opts)?,
            reads: self.reads.clone(),
            writes: self.writes.clone(),
        }))
    }

    fn delete(&self, path: &str) -> IoResult<()> {
        self.inner.delete(path)
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

impl StorageFile for CountingFile {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> IoResult<usize> {
        self.reads.fetch_add(1, Ordering::SeqCst);
        self.inner.read_at(offset, buf)
    }

    fn write_at(&self, offset: u64, buf: &[u8]) -> IoResult<usize> {
        self.writes.fetch_add(1, Ordering::SeqCst);
        self.inner.write_at(offset, buf)
    }

    fn size(&self) -> IoResult<u64> {
        self.inner.size()
    }

    fn set_size(&self, size: u64) -> IoResult<()> {
        self.inner.set_size(size)
    }

    fn preallocate(&self, size: u64) -> IoResult<()> {
        self.inner.preallocate(size)
    }

    fn sync(&self) -> IoResult<()> {
        self.inner.sync()
    }

    fn map(&self, offset: u64, len: usize, writable: bool) -> IoResult<Box<dyn MappedRegion>> {
        self.inner.map(offset, len, writable)
    }

    fn lock_exclusive(&self) -> IoResult<FileLockGuard> {
        self.inner.lock_exclusive()
    }

    fn backend_name(&self) -> &'static str {
        "counting"
    }
}

#[test]
fn repeated_same_shape_access_reuses_the_plan_but_still_hits_storage() {
    let path = format!("/tmp/jpio-plancache-{}", std::process::id());
    let reads = Arc::new(AtomicUsize::new(0));
    let writes = Arc::new(AtomicUsize::new(0));
    let backend = Arc::new(CountingBackend {
        inner: LocalBackend::instant(),
        reads: reads.clone(),
        writes: writes.clone(),
    });
    threads::run(1, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        // A strided file view: compiling its plan walks the filetype map,
        // which is exactly the work the cache exists to skip.
        let ft = Datatype::vector(1, 2, 4, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, 16).unwrap();
        f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        let data: Vec<i32> = (0..32).collect();

        f.write_at(0, data.as_slice(), 0, 32, &Datatype::INT).unwrap();
        let s0 = f.plan_cache_stats();
        assert_eq!(s0.hits, 0, "first access of a shape cannot hit");
        assert!(s0.misses >= 1);
        let w0 = writes.load(Ordering::SeqCst);
        assert!(w0 > 0, "the write must reach storage");

        // The repeated same-shape access: same (view, direction, offset,
        // len) — the plan is reused, no recompilation...
        f.write_at(0, data.as_slice(), 0, 32, &Datatype::INT).unwrap();
        let s1 = f.plan_cache_stats();
        assert_eq!(s1.hits, 1, "repeated same-shape write must reuse the compiled plan");
        assert_eq!(s1.misses, s0.misses, "repeated same-shape write must not recompile");
        // ...but the storage I/O still happens (as many writes as round 1).
        let w1 = writes.load(Ordering::SeqCst);
        assert_eq!(w1, 2 * w0, "the repeated write must hit storage like the first");

        // Same shape, other direction: a distinct key.
        let mut back = vec![0i32; 32];
        f.read_at(0, back.as_mut_slice(), 0, 32, &Datatype::INT).unwrap();
        let s2 = f.plan_cache_stats();
        assert_eq!((s2.hits, s2.misses), (1, s1.misses + 1));
        f.read_at(0, back.as_mut_slice(), 0, 32, &Datatype::INT).unwrap();
        assert_eq!(
            f.plan_cache_stats(),
            PlanCacheStats { hits: 2, misses: s2.misses },
            "repeated read reuses its plan"
        );
        assert_eq!(back, data);
        assert!(reads.load(Ordering::SeqCst) > 0);

        // A different shape misses; the old shape stays cached.
        f.write_at(4, data.as_slice(), 0, 16, &Datatype::INT).unwrap();
        let s3 = f.plan_cache_stats();
        assert_eq!((s3.hits, s3.misses), (2, s2.misses + 1));
        f.write_at(0, data.as_slice(), 0, 32, &Datatype::INT).unwrap();
        assert_eq!(f.plan_cache_stats(), PlanCacheStats { hits: 3, misses: s3.misses });

        // set_view installs a new view identity: same shape recompiles.
        let ft2 = Datatype::vector(1, 2, 4, &Datatype::INT).unwrap();
        let ft2 = Datatype::resized(&ft2, 0, 16).unwrap();
        f.set_view(0, &Datatype::INT, &ft2, "native", &Info::null()).unwrap();
        f.write_at(0, data.as_slice(), 0, 32, &Datatype::INT).unwrap();
        let s4 = f.plan_cache_stats();
        assert_eq!(s4.hits, 3, "a new view identity must not hit stale plans");
        assert_eq!(s4.misses, s3.misses + 1);

        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}
