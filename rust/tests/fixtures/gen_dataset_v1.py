#!/usr/bin/env python3
"""Generate the frozen v1 dataset-container golden fixture.

Mirrors ``Header::encode`` (rust/src/dataset/header.rs) and the
``layout`` rules of rust/src/dataset/mod.rs byte for byte:

* dims   time=UNLIMITED, x=2, y=3
* attrs  title = "golden fixture"
* vars   grid  INT    external32  [x, y]  units="K"   (fixed)
         t     DOUBLE native      [time]              (record)
* data   grid = 1..6 big-endian at data_start (4096)
         t    = 10.5, 11.5 little-endian records at rec_start (4120)
* num_recs = 2

The committed ``dataset_v1.jpds`` must keep decoding — and re-encoding
byte-identically — under every future revision of the codec; a change
that breaks the drift test in tests/dataset_roundtrip.rs is a format
break and needs a version bump, not a fixture refresh.
"""

import struct
from pathlib import Path

DATA_START = 4096  # align_up(header_len, 4096)
REC_START = 4120  # DATA_START + align_up(2*3*4, 8)
REC_SIZE = 8  # one f64 per record row


def put_bytes(out: bytearray, b: bytes) -> None:
    out += struct.pack("<I", len(b)) + b


def header() -> bytearray:
    out = bytearray()
    out += b"JPDS"
    out += struct.pack("<I", 1)  # version
    out += struct.pack("<Q", 0)  # header_bytes, patched below
    out += struct.pack("<Q", 2)  # num_recs
    out += struct.pack("<Q", DATA_START)
    out += struct.pack("<Q", REC_START)
    out += struct.pack("<Q", REC_SIZE)
    out += struct.pack("<III", 3, 1, 2)  # ndims, nattrs, nvars
    for name, length in [(b"time", 0), (b"x", 2), (b"y", 3)]:
        put_bytes(out, name)
        out += struct.pack("<Q", length)
    put_bytes(out, b"title")
    put_bytes(out, b"golden fixture")
    # grid: prim Int (2), external32, dims [x, y], units="K", fixed.
    put_bytes(out, b"grid")
    out += bytes([2, 1])
    out += struct.pack("<I", 2) + struct.pack("<II", 1, 2)
    out += struct.pack("<I", 1)
    put_bytes(out, b"units")
    put_bytes(out, b"K")
    out += struct.pack("<Q", DATA_START)
    # t: prim Double (5), native, dims [time], record (row offset 0).
    put_bytes(out, b"t")
    out += bytes([5, 0])
    out += struct.pack("<I", 1) + struct.pack("<I", 0)
    out += struct.pack("<I", 0)
    out += struct.pack("<Q", 0)
    struct.pack_into("<Q", out, 8, len(out))
    return out


def main() -> None:
    hdr = header()
    assert len(hdr) <= DATA_START, len(hdr)
    blob = bytearray(REC_START + 2 * REC_SIZE)
    blob[: len(hdr)] = hdr
    for i, v in enumerate([1, 2, 3, 4, 5, 6]):
        struct.pack_into(">i", blob, DATA_START + 4 * i, v)
    struct.pack_into("<d", blob, REC_START, 10.5)
    struct.pack_into("<d", blob, REC_START + REC_SIZE, 11.5)
    out = Path(__file__).with_name("dataset_v1.jpds")
    out.write_bytes(blob)
    print(f"wrote {out} ({len(blob)} bytes, header {len(hdr)} bytes)")


if __name__ == "__main__":
    main()
