//! Matrix-sweep property test: every public data-access wrapper must
//! produce byte-identical file contents and equal `Status` counts to the
//! `AccessOp` core submit path (`File::submit_read` / `File::submit_write`
//! / `File::submit_read_owned`).
//!
//! The sweep enumerates the legal (positioning × coordination ×
//! synchronism) cells derived by `io::op` — split `*_begin`/`*_end`
//! executed as one pair — crossed with {contiguous, vector-view} file
//! views and {native, external32} data representations. Each scenario
//! runs twice on a 2-rank world (once through the wrapper, once through
//! a directly-constructed `AccessOp`) and the two runs must agree on the
//! raw file bytes, the per-rank write/read `Status`, and the data read
//! back.

use jpio::comm::{threads, Comm, Datatype};
use jpio::io::op::cell_is_legal;
use jpio::io::{
    amode, seek, AccessOp, Coordination, File, Info, Positioning, PositioningKind, SplitPhase,
    Synchronism,
};

const K: usize = 16; // ints per rank per transfer

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Mode {
    Blocking,
    Nonblocking,
    SplitPair,
}

impl Mode {
    fn sync(self) -> Synchronism {
        match self {
            Mode::Blocking => Synchronism::Blocking,
            Mode::Nonblocking => Synchronism::Nonblocking,
            Mode::SplitPair => Synchronism::Split(SplitPhase::Begin),
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ViewKind {
    Contig,
    Vector,
}

/// Per-rank observation of one scenario run.
type RankResult = (usize, usize, Option<usize>, Vec<i32>);

fn positioning_for(pos: PositioningKind, off: i64) -> Positioning {
    match pos {
        PositioningKind::Explicit => Positioning::Explicit(off),
        PositioningKind::Individual => Positioning::Individual,
        PositioningKind::Shared => Positioning::Shared,
    }
}

fn set_view_for(f: &File<'_>, view: ViewKind, datarep: &str, rank: usize, n: usize) {
    match view {
        ViewKind::Contig => {
            f.set_view(0, &Datatype::INT, &Datatype::INT, datarep, &Info::null()).unwrap()
        }
        ViewKind::Vector => {
            // The canonical interleave: rank r owns every n-th int.
            let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
            let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
            f.set_view((rank * 4) as i64, &Datatype::INT, &ft, datarep, &Info::null()).unwrap()
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn do_write(
    f: &File<'_>,
    pos: PositioningKind,
    coord: Coordination,
    mode: Mode,
    use_core: bool,
    off: i64,
    data: &[i32],
) -> usize {
    let dt = Datatype::INT;
    if pos == PositioningKind::Individual {
        f.seek(off, seek::SET).unwrap();
    }
    if use_core {
        return match mode {
            Mode::Blocking => {
                let op = AccessOp::write(positioning_for(pos, off), coord, mode.sync(), 0, K, &dt);
                f.submit_write(&op, data).unwrap().status().unwrap().bytes
            }
            Mode::Nonblocking => {
                let op = AccessOp::write(positioning_for(pos, off), coord, mode.sync(), 0, K, &dt);
                f.submit_write(&op, data).unwrap().request().unwrap().wait().unwrap().0.bytes
            }
            Mode::SplitPair => {
                let op = AccessOp::write(positioning_for(pos, off), coord, mode.sync(), 0, K, &dt);
                f.submit_write(&op, data).unwrap().begun().unwrap();
                let end = AccessOp::write(
                    positioning_for(pos, 0),
                    coord,
                    Synchronism::Split(SplitPhase::End),
                    0,
                    0,
                    &Datatype::BYTE,
                );
                f.submit_write(&end, [0u8; 0].as_slice()).unwrap().status().unwrap().bytes
            }
        };
    }
    match (pos, coord, mode) {
        (PositioningKind::Explicit, Coordination::Independent, Mode::Blocking) => {
            f.write_at(off, data, 0, K, &dt).unwrap().bytes
        }
        (PositioningKind::Explicit, Coordination::Independent, Mode::Nonblocking) => {
            f.iwrite_at(off, data, 0, K, &dt).unwrap().wait().unwrap().0.bytes
        }
        (PositioningKind::Explicit, Coordination::Collective, Mode::Blocking) => {
            f.write_at_all(off, data, 0, K, &dt).unwrap().bytes
        }
        (PositioningKind::Explicit, Coordination::Collective, Mode::Nonblocking) => {
            f.iwrite_at_all(off, data, 0, K, &dt).unwrap().wait().unwrap().0.bytes
        }
        (PositioningKind::Explicit, Coordination::Collective, Mode::SplitPair) => {
            f.write_at_all_begin(off, data, 0, K, &dt).unwrap();
            f.write_at_all_end().unwrap().bytes
        }
        (PositioningKind::Individual, Coordination::Independent, Mode::Blocking) => {
            f.write(data, 0, K, &dt).unwrap().bytes
        }
        (PositioningKind::Individual, Coordination::Independent, Mode::Nonblocking) => {
            f.iwrite(data, 0, K, &dt).unwrap().wait().unwrap().0.bytes
        }
        (PositioningKind::Individual, Coordination::Collective, Mode::Blocking) => {
            f.write_all(data, 0, K, &dt).unwrap().bytes
        }
        (PositioningKind::Individual, Coordination::Collective, Mode::Nonblocking) => {
            f.iwrite_all(data, 0, K, &dt).unwrap().wait().unwrap().0.bytes
        }
        (PositioningKind::Individual, Coordination::Collective, Mode::SplitPair) => {
            f.write_all_begin(data, 0, K, &dt).unwrap();
            f.write_all_end().unwrap().bytes
        }
        (PositioningKind::Shared, Coordination::Independent, Mode::Blocking) => {
            f.write_shared(data, 0, K, &dt).unwrap().bytes
        }
        (PositioningKind::Shared, Coordination::Independent, Mode::Nonblocking) => {
            f.iwrite_shared(data, 0, K, &dt).unwrap().wait().unwrap().0.bytes
        }
        (PositioningKind::Shared, Coordination::Ordered, Mode::Blocking) => {
            f.write_ordered(data, 0, K, &dt).unwrap().bytes
        }
        (PositioningKind::Shared, Coordination::Ordered, Mode::SplitPair) => {
            f.write_ordered_begin(data, 0, K, &dt).unwrap();
            f.write_ordered_end().unwrap().bytes
        }
        other => panic!("no write wrapper for cell {other:?}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn do_read(
    f: &File<'_>,
    pos: PositioningKind,
    coord: Coordination,
    mode: Mode,
    use_core: bool,
    off: i64,
    back: &mut [i32],
) -> (usize, Option<usize>) {
    let dt = Datatype::INT;
    if pos == PositioningKind::Individual {
        f.seek(off, seek::SET).unwrap();
    }
    let st = if use_core {
        match mode {
            Mode::Blocking => {
                let op = AccessOp::read(positioning_for(pos, off), coord, mode.sync(), 0, K, &dt);
                f.submit_read(&op, back).unwrap()
            }
            Mode::Nonblocking => {
                let op = AccessOp::read(positioning_for(pos, off), coord, mode.sync(), 0, K, &dt);
                let (st, buf) = f.submit_read_owned(&op, vec![0i32; K]).unwrap().wait().unwrap();
                back.copy_from_slice(&buf);
                st
            }
            Mode::SplitPair => {
                let op = AccessOp::read(positioning_for(pos, off), coord, mode.sync(), 0, K, &dt);
                f.submit_read(&op, [0u8; 0].as_mut_slice()).unwrap();
                let end = AccessOp::read(
                    positioning_for(pos, 0),
                    coord,
                    Synchronism::Split(SplitPhase::End),
                    0,
                    K,
                    &dt,
                );
                f.submit_read(&end, back).unwrap()
            }
        }
    } else {
        match (pos, coord, mode) {
            (PositioningKind::Explicit, Coordination::Independent, Mode::Blocking) => {
                f.read_at(off, back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Explicit, Coordination::Independent, Mode::Nonblocking) => {
                let (st, buf) = f.iread_at(off, vec![0i32; K], 0, K, &dt).unwrap().wait().unwrap();
                back.copy_from_slice(&buf);
                st
            }
            (PositioningKind::Explicit, Coordination::Collective, Mode::Blocking) => {
                f.read_at_all(off, back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Explicit, Coordination::Collective, Mode::Nonblocking) => {
                let (st, buf) =
                    f.iread_at_all(off, vec![0i32; K], 0, K, &dt).unwrap().wait().unwrap();
                back.copy_from_slice(&buf);
                st
            }
            (PositioningKind::Explicit, Coordination::Collective, Mode::SplitPair) => {
                f.read_at_all_begin(off, K, &dt).unwrap();
                f.read_at_all_end(back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Individual, Coordination::Independent, Mode::Blocking) => {
                f.read(back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Individual, Coordination::Independent, Mode::Nonblocking) => {
                let (st, buf) = f.iread(vec![0i32; K], 0, K, &dt).unwrap().wait().unwrap();
                back.copy_from_slice(&buf);
                st
            }
            (PositioningKind::Individual, Coordination::Collective, Mode::Blocking) => {
                f.read_all(back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Individual, Coordination::Collective, Mode::Nonblocking) => {
                let (st, buf) = f.iread_all(vec![0i32; K], 0, K, &dt).unwrap().wait().unwrap();
                back.copy_from_slice(&buf);
                st
            }
            (PositioningKind::Individual, Coordination::Collective, Mode::SplitPair) => {
                f.read_all_begin(K, &dt).unwrap();
                f.read_all_end(back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Shared, Coordination::Independent, Mode::Blocking) => {
                f.read_shared(back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Shared, Coordination::Independent, Mode::Nonblocking) => {
                let (st, buf) = f.iread_shared(vec![0i32; K], 0, K, &dt).unwrap().wait().unwrap();
                back.copy_from_slice(&buf);
                st
            }
            (PositioningKind::Shared, Coordination::Ordered, Mode::Blocking) => {
                f.read_ordered(back, 0, K, &dt).unwrap()
            }
            (PositioningKind::Shared, Coordination::Ordered, Mode::SplitPair) => {
                f.read_ordered_begin(K, &dt).unwrap();
                f.read_ordered_end(back, 0, K, &dt).unwrap()
            }
            other => panic!("no read wrapper for cell {other:?}"),
        }
    };
    (st.bytes, st.count(&dt))
}

/// One full scenario: write each rank's slot through the cell, then read
/// it back through the same cell. Returns per-rank
/// `(write_bytes, read_bytes, read_count, data_read_back)`.
fn run_scenario(
    pos: PositioningKind,
    coord: Coordination,
    mode: Mode,
    view: ViewKind,
    datarep: &str,
    use_core: bool,
    path: &str,
) -> Vec<RankResult> {
    threads::run(2, |c| {
        let f = File::open(c, path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let n = c.size();
        let r = c.rank();
        set_view_for(&f, view, datarep, r, n);
        // Shared-pointer *independent* cells are noncollective and their
        // rank interleave is nondeterministic by spec; rank 0 performs
        // the transfer alone so both runs are comparable.
        let participates = !(pos == PositioningKind::Shared && coord == Coordination::Independent)
            || r == 0;
        let off = match view {
            ViewKind::Contig => (r * K) as i64,
            ViewKind::Vector => 0,
        };
        let data: Vec<i32> = (0..K as i32).map(|i| (r as i32 + 1) * 1000 + i).collect();
        let wbytes = if participates { do_write(&f, pos, coord, mode, use_core, off, &data) } else { 0 };
        c.barrier();
        if pos == PositioningKind::Shared {
            f.seek_shared(0, seek::SET).unwrap(); // collective
        }
        let mut back = vec![0i32; K];
        let (rbytes, rcount) = if participates {
            do_read(&f, pos, coord, mode, use_core, off, back.as_mut_slice())
        } else {
            (0, None)
        };
        if participates {
            assert_eq!(back, data, "cell {pos:?}/{coord:?}/{mode:?} corrupted its data");
        }
        c.barrier();
        f.close().unwrap();
        (wbytes, rbytes, rcount, back)
    })
}

fn sweep(cells: &[(PositioningKind, Coordination, Mode)], tag: &str) {
    for &(pos, coord, mode) in cells {
        assert!(
            cell_is_legal(pos, coord, mode.sync()),
            "sweep enumerates an illegal cell {pos:?}/{coord:?}/{mode:?}"
        );
        for view in [ViewKind::Contig, ViewKind::Vector] {
            for datarep in ["native", "external32"] {
                let base = format!(
                    "/tmp/jpio-opmatrix-{}-{tag}-{pos:?}-{coord:?}-{mode:?}-{view:?}-{datarep}",
                    std::process::id()
                );
                let wrapper_path = format!("{base}-wrapper.dat");
                let core_path = format!("{base}-core.dat");
                let via_wrapper =
                    run_scenario(pos, coord, mode, view, datarep, false, &wrapper_path);
                let via_core = run_scenario(pos, coord, mode, view, datarep, true, &core_path);
                assert_eq!(
                    via_wrapper, via_core,
                    "wrapper and core Status/data disagree for \
                     {pos:?}/{coord:?}/{mode:?}/{view:?}/{datarep}"
                );
                let wrapper_bytes = std::fs::read(&wrapper_path).unwrap();
                let core_bytes = std::fs::read(&core_path).unwrap();
                assert_eq!(
                    wrapper_bytes, core_bytes,
                    "wrapper and core file contents disagree for \
                     {pos:?}/{coord:?}/{mode:?}/{view:?}/{datarep}"
                );
                File::delete(&wrapper_path, &Info::null()).unwrap();
                File::delete(&core_path, &Info::null()).unwrap();
            }
        }
    }
}

#[test]
fn independent_cells_match_core() {
    sweep(
        &[
            (PositioningKind::Explicit, Coordination::Independent, Mode::Blocking),
            (PositioningKind::Explicit, Coordination::Independent, Mode::Nonblocking),
            (PositioningKind::Individual, Coordination::Independent, Mode::Blocking),
            (PositioningKind::Individual, Coordination::Independent, Mode::Nonblocking),
            (PositioningKind::Shared, Coordination::Independent, Mode::Blocking),
            (PositioningKind::Shared, Coordination::Independent, Mode::Nonblocking),
        ],
        "indep",
    );
}

#[test]
fn collective_cells_match_core() {
    sweep(
        &[
            (PositioningKind::Explicit, Coordination::Collective, Mode::Blocking),
            (PositioningKind::Explicit, Coordination::Collective, Mode::Nonblocking),
            (PositioningKind::Explicit, Coordination::Collective, Mode::SplitPair),
            (PositioningKind::Individual, Coordination::Collective, Mode::Blocking),
            (PositioningKind::Individual, Coordination::Collective, Mode::Nonblocking),
            (PositioningKind::Individual, Coordination::Collective, Mode::SplitPair),
        ],
        "coll",
    );
}

#[test]
fn ordered_cells_match_core() {
    sweep(
        &[
            (PositioningKind::Shared, Coordination::Ordered, Mode::Blocking),
            (PositioningKind::Shared, Coordination::Ordered, Mode::SplitPair),
        ],
        "ordered",
    );
}

#[test]
fn sweep_covers_every_derived_write_cell() {
    // The three sweeps above plus this census: every legal (positioning,
    // coordination, synchronism-mode) combination is exercised. (BEGIN
    // and END are one executed pair.)
    let mut legal = 0;
    for pos in
        [PositioningKind::Explicit, PositioningKind::Individual, PositioningKind::Shared]
    {
        for coord in
            [Coordination::Independent, Coordination::Collective, Coordination::Ordered]
        {
            for mode in [Mode::Blocking, Mode::Nonblocking, Mode::SplitPair] {
                if cell_is_legal(pos, coord, mode.sync()) {
                    legal += 1;
                }
            }
        }
    }
    // 6 independent + 6 collective + 2 ordered == the 14 pair-collapsed
    // cells the sweeps enumerate.
    assert_eq!(legal, 14);
}
