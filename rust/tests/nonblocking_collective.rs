//! The MPI-3.1 nonblocking collectives (`iread_all`/`iwrite_all` and the
//! `_at_` variants) and the split-collective state machine, exercised
//! under both threaded and *forked-process* communicators — the paths
//! where the request engine is absent in the child (inline fallback),
//! the exchange crosses address spaces, and buffer ownership must round
//! trip through the request.

use std::sync::Arc;

use jpio::comm::{process, threads, Comm, Datatype};
use jpio::io::{amode, ErrorClass, File, Info};
use jpio::storage::striped::StripedBackend;
use jpio::storage::Backend;

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-nbcoll-{}-{name}", std::process::id())
}

#[test]
fn iwrite_iread_at_all_across_processes() {
    // Forked ranks: the exchange phase crosses real address spaces and
    // the I/O phase falls back to inline execution (no engine workers in
    // the child) — completion must still be correct.
    let path = tmp("procs");
    process::run_local(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let mine: Vec<i32> = (0..512).map(|i| (r * 512 + i) as i32).collect();
        let req = f
            .iwrite_at_all((r * 512) as i64, mine.as_slice(), 0, 512, &Datatype::INT)
            .unwrap();
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 2048);
        c.barrier();
        let n = 512 * c.size();
        let req = f.iread_at_all(0, vec![0i32; n], 0, n, &Datatype::INT).unwrap();
        let (st, all) = req.wait().unwrap();
        assert_eq!(st.bytes, n * 4);
        assert_eq!(all, (0..n as i32).collect::<Vec<_>>());
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn iwrite_all_strided_on_striped_across_processes() {
    // The full stack at once: forked ranks, strided interleave, striped
    // storage, nonblocking collective writes with pointer advance.
    let path = tmp("striped");
    process::run_local(4, |c| {
        let backend: Arc<dyn Backend> = Arc::new(StripedBackend::local(4, 64));
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend,
        )
        .unwrap();
        let n = c.size();
        let r = c.rank();
        let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, (n * 4) as i64).unwrap();
        f.set_view((r * 4) as i64, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        let k = 256;
        let mine: Vec<i32> = (0..k).map(|i| (i * n + r) as i32).collect();
        let req = f.iwrite_all(mine.as_slice(), 0, k, &Datatype::INT).unwrap();
        assert_eq!(f.get_position().unwrap(), k as i64, "pointer advances at call");
        req.wait().unwrap();
        c.barrier();
        f.seek(0, jpio::io::seek::SET).unwrap();
        let req = f.iread_all(vec![0i32; k], 0, k, &Datatype::INT).unwrap();
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, k * 4);
        assert_eq!(back, mine);
        f.close().unwrap();
    });
    let b = StripedBackend::local(4, 64);
    b.delete(&path).unwrap();
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn mismatched_split_end_across_processes() {
    // The split state machine across address spaces: a wrong-kind END is
    // rejected on every rank, the pending BEGIN survives, a second END
    // after completion ("double wait") is rejected too.
    let path = tmp("mismatch");
    process::run_local(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank() as i64;
        let mine = vec![c.rank() as i32; 64];
        f.write_at_all_begin(r * 64, mine.as_slice(), 0, 64, &Datatype::INT).unwrap();
        // Wrong END kind: rejected, state preserved.
        let mut buf = vec![0i32; 64];
        let err = f.read_at_all_end(buf.as_mut_slice(), 0, 64, &Datatype::INT).unwrap_err();
        assert_eq!(err.class, ErrorClass::Request);
        // Matching END completes.
        let st = f.write_at_all_end().unwrap();
        assert_eq!(st.bytes, 256);
        // Completing again — the runtime analogue of a double wait — is
        // an error, not a hang or a double write.
        assert_eq!(f.write_at_all_end().unwrap_err().class, ErrorClass::Request);
        c.barrier();
        let mut back = vec![0i32; 64];
        f.read_at(r * 64, back.as_mut_slice(), 0, 64, &Datatype::INT).unwrap();
        assert!(back.iter().all(|&v| v == c.rank() as i32));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn buffer_ownership_round_trips_through_requests() {
    let path = tmp("ownership");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        // iwrite_at_all snapshots the data at the call: mutating the
        // buffer between the call and the wait must not affect the file.
        let mut mine = vec![(r + 1) as i32; 128];
        let req = f
            .iwrite_at_all((r * 128) as i64, mine.as_slice(), 0, 128, &Datatype::INT)
            .unwrap();
        mine.iter_mut().for_each(|v| *v = -999);
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 512);
        c.barrier();
        // iread_at_all takes ownership of the Vec and returns the same
        // allocation filled; Rust's move semantics make a second wait on
        // the same request unrepresentable (wait consumes it).
        let mut buf: Vec<i32> = Vec::with_capacity(4096);
        buf.resize(256, 0);
        let cap = buf.capacity();
        let mut req = f.iread_at_all(buf, 0, 256, &Datatype::INT).unwrap();
        // Poll (MPI_Test) until complete, then wait — test-then-wait is
        // the sanctioned double-completion pattern.
        loop {
            if let Some(res) = req.test() {
                assert!(res.is_ok());
                break;
            }
            std::thread::yield_now();
        }
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, 1024);
        assert!(back.capacity() >= cap, "request must return the same allocation");
        assert!(back[..128].iter().all(|&v| v == 1));
        assert!(back[128..].iter().all(|&v| v == 2));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn zero_size_participants_complete() {
    // Ranks contributing nothing to a nonblocking collective must still
    // complete (empty plans, empty exchange legs).
    let path = tmp("zero");
    threads::run(3, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let r = c.rank();
        let mine = vec![r as i32; 32];
        let count = if r == 1 { 0 } else { 32 };
        let req = f
            .iwrite_at_all((r * 32) as i64, mine.as_slice(), 0, count, &Datatype::INT)
            .unwrap();
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, count * 4);
        c.barrier();
        let req = f.iread_at_all(0, vec![0i32; 96], 0, 96, &Datatype::INT).unwrap();
        let (st, back) = req.wait().unwrap();
        // Rank 1 wrote nothing: its block reads as zeros (hole) up to the
        // written extent of rank 2's block.
        assert_eq!(st.bytes, 96 * 4);
        assert!(back[..32].iter().all(|&v| v == 0));
        assert!(back[32..64].iter().all(|&v| v == 0));
        assert!(back[64..].iter().all(|&v| v == 2));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}
