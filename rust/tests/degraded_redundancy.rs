//! Redundant stripes (replica/parity) under fault injection: a killed
//! stripe server must degrade service — correct bytes plus
//! `ErrorClass::Degraded` advisories — instead of corrupting or failing
//! the file, for independent access, whole-plan dispatch, and the
//! two-phase collective path (exchange above, reconstruction below, per
//! Thakur-style two-phase I/O). Failures beyond the mode's tolerance
//! still surface as plain errors.

use std::sync::Arc;

use jpio::comm::{threads, Comm, Datatype};
use jpio::io::{amode, ErrorClass, File, Info};
use jpio::storage::faults::{FaultBackend, FaultOp, FaultPlan, FaultRule};
use jpio::storage::layout::Redundancy;
use jpio::storage::local::LocalBackend;
use jpio::storage::striped::StripedBackend;
use jpio::storage::{Backend, OpenOptions, StorageFile};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-degraded-{}-{name}", std::process::id())
}

/// A striped backend over `factor` local children where `victim` is
/// wrapped with an (initially empty) fault plan — kill it later with
/// `plan.inject_kill(..)`.
fn backend_with_victim(
    factor: usize,
    unit: u64,
    redundancy: Redundancy,
    victim: usize,
) -> (StripedBackend, Arc<FaultPlan>) {
    let plan = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..factor)
        .map(|i| {
            if i == victim {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let b = StripedBackend::with_redundancy(children, unit, redundancy).unwrap();
    (b, plan)
}

fn assert_all_degraded(advisories: &[jpio::io::IoError]) {
    assert!(!advisories.is_empty(), "degraded operation must leave an advisory");
    for a in advisories {
        assert_eq!(a.class, ErrorClass::Degraded, "{a}");
        assert!(a.to_string().contains("JPIO_ERR_DEGRADED"), "{a}");
    }
}

// ----------------------------------------------------------------------
// Raw backend surface: reads after a server dies
// ----------------------------------------------------------------------

#[test]
fn replica_read_survives_any_single_dead_server() {
    for victim in 0..4 {
        let (b, plan) = backend_with_victim(4, 8, Redundancy::Replica(2), victim);
        let path = tmp(&format!("rep-read-{victim}"));
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let data: Vec<u8> = (0..200u8).collect();
        f.write_at(3, &data).unwrap();
        assert!(f.take_advisories().is_empty(), "healthy write must not degrade");
        plan.inject_kill(ErrorClass::Io);
        let mut back = vec![0u8; 200];
        assert_eq!(f.read_at(3, &mut back).unwrap(), 200, "victim {victim}");
        assert_eq!(back, data, "victim {victim}");
        assert_all_degraded(&f.take_advisories());
        // Advisories are drained, not repeated forever.
        let mut again = vec![0u8; 200];
        f.read_at(3, &mut again).unwrap();
        assert_eq!(again, data);
        assert_all_degraded(&f.take_advisories());
        b.delete(&path).unwrap();
    }
}

#[test]
fn parity_read_survives_any_single_dead_server() {
    for victim in 0..4 {
        let (b, plan) = backend_with_victim(4, 8, Redundancy::Parity, victim);
        let path = tmp(&format!("par-read-{victim}"));
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        let data: Vec<u8> = (0..251u8).cycle().take(500).collect();
        f.write_at(0, &data).unwrap();
        // Overwrite a middle range so reconstruction also covers
        // read-modify-written rows.
        f.write_at(100, &[0xA5u8; 60]).unwrap();
        let mut want = data.clone();
        want[100..160].fill(0xA5);
        assert!(f.take_advisories().is_empty());
        plan.inject_kill(ErrorClass::Io);
        let mut back = vec![0u8; 500];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 500, "victim {victim}");
        assert_eq!(back, want, "victim {victim}");
        assert_all_degraded(&f.take_advisories());
        b.delete(&path).unwrap();
    }
}

#[test]
fn degraded_vectored_runs_and_sparse_holes() {
    let (b, plan) = backend_with_victim(4, 8, Redundancy::Parity, 1);
    let path = tmp("par-runs");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    let data: Vec<u8> = (0..59u8).collect();
    let runs = [(3u64, 20usize), (40, 9), (100, 30)];
    f.write_runs(&runs, &data).unwrap();
    plan.inject_kill(ErrorClass::Io);
    let mut back = vec![0u8; 59];
    assert_eq!(f.read_runs(&runs, &mut back).unwrap(), 59);
    assert_eq!(back, data);
    // Sparse hole between the runs still reads as zeros, reconstructed
    // or not.
    let mut hole = vec![0xEEu8; 10];
    assert_eq!(f.read_at(60, &mut hole).unwrap(), 10);
    assert!(hole.iter().all(|&v| v == 0), "reconstructed holes must stay zero");
    assert_all_degraded(&f.take_advisories());
    b.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// Raw backend surface: writes while a server is dead
// ----------------------------------------------------------------------

#[test]
fn replica_write_survives_dead_server_and_reads_back() {
    for victim in 0..3 {
        let (b, plan) = backend_with_victim(3, 8, Redundancy::Replica(2), victim);
        let path = tmp(&format!("rep-write-{victim}"));
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        plan.inject_kill(ErrorClass::Io);
        let data: Vec<u8> = (0..150u8).collect();
        assert_eq!(f.write_at(7, &data).unwrap(), 150, "victim {victim}");
        assert_all_degraded(&f.take_advisories());
        assert_eq!(f.size().unwrap(), 157);
        let mut back = vec![0u8; 150];
        assert_eq!(f.read_at(7, &mut back).unwrap(), 150);
        assert_eq!(back, data, "victim {victim}");
        f.take_advisories();
        b.delete(&path).unwrap();
    }
}

#[test]
fn parity_write_survives_dead_server_and_reads_back() {
    for victim in 0..4 {
        let (b, plan) = backend_with_victim(4, 8, Redundancy::Parity, victim);
        let path = tmp(&format!("par-write-{victim}"));
        let f = b.open(&path, OpenOptions::rw_create()).unwrap();
        // Seed healthy data so the degraded write also exercises the
        // reconstruct-old-rows path of the parity RMW.
        f.write_at(0, &[0x11u8; 96]).unwrap();
        plan.inject_kill(ErrorClass::Io);
        let data: Vec<u8> = (0..120u8).collect();
        assert_eq!(f.write_at(13, &data).unwrap(), 120, "victim {victim}");
        assert_all_degraded(&f.take_advisories());
        let mut want = vec![0x11u8; 133];
        want[13..133].copy_from_slice(&data);
        let mut back = vec![0u8; 133];
        assert_eq!(f.read_at(0, &mut back).unwrap(), 133);
        assert_eq!(back, want, "victim {victim}");
        f.take_advisories();
        b.delete(&path).unwrap();
    }
}

#[test]
fn parity_grow_set_size_succeeds_on_degraded_file() {
    let (b, plan) = backend_with_victim(4, 8, Redundancy::Parity, 0);
    let path = tmp("par-grow");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.write_at(0, &[3u8; 50]).unwrap();
    plan.inject_kill(ErrorClass::Io);
    // Growth appends zeros and needs no parity repair, so it must not
    // trip over the dead server's intercepted read path.
    f.set_size(100).unwrap();
    assert_eq!(f.size().unwrap(), 100);
    let mut back = vec![0u8; 100];
    assert_eq!(f.read_at(0, &mut back).unwrap(), 100);
    assert!(back[..50].iter().all(|&v| v == 3), "data lost growing degraded file");
    assert!(back[50..].iter().all(|&v| v == 0), "grown region must read zeros");
    f.take_advisories();
    b.delete(&path).unwrap();
}

#[test]
fn failures_beyond_tolerance_are_errors() {
    // Parity tolerates one lost server, not two.
    let plan0 = FaultPlan::kill(ErrorClass::Io);
    let plan2 = FaultPlan::kill(ErrorClass::NoSpace);
    let children: Vec<Arc<dyn Backend>> = vec![
        Arc::new(FaultBackend::new(LocalBackend::instant(), plan0)),
        Arc::new(LocalBackend::instant()),
        Arc::new(FaultBackend::new(LocalBackend::instant(), plan2)),
        Arc::new(LocalBackend::instant()),
    ];
    let b = StripedBackend::with_redundancy(children, 8, Redundancy::Parity).unwrap();
    let path = tmp("two-dead");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    let err = f.write_at(0, &[1u8; 64]).unwrap_err();
    assert_eq!(err.class, ErrorClass::Io, "first failed server's class surfaces");
    assert!(f.take_advisories().is_empty(), "a failed op must not also advise");
    // No redundancy at all: a single fault is already an error (the
    // pre-PR 3 behaviour is preserved).
    let (b2, plan) = backend_with_victim(4, 8, Redundancy::None, 2);
    let path2 = tmp("none-dead");
    let f2 = b2.open(&path2, OpenOptions::rw_create()).unwrap();
    f2.write_at(0, &[2u8; 64]).unwrap();
    plan.inject_kill(ErrorClass::Io);
    let mut back = [0u8; 64];
    assert_eq!(f2.read_at(0, &mut back).unwrap_err().class, ErrorClass::Io);
    let _ = b.delete(&path);
    let _ = b2.delete(&path2);
}

#[test]
fn replica3_tolerates_two_dead_servers() {
    let plan_a = FaultPlan::new(vec![]);
    let plan_b = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = vec![
        Arc::new(FaultBackend::new(LocalBackend::instant(), plan_a.clone())),
        Arc::new(LocalBackend::instant()),
        Arc::new(FaultBackend::new(LocalBackend::instant(), plan_b.clone())),
        Arc::new(LocalBackend::instant()),
    ];
    let b = StripedBackend::with_redundancy(children, 8, Redundancy::Replica(3)).unwrap();
    let path = tmp("rep3");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    let data: Vec<u8> = (0..160u8).collect();
    f.write_at(0, &data).unwrap();
    plan_a.inject_kill(ErrorClass::Io);
    plan_b.inject_kill(ErrorClass::Io);
    let mut back = vec![0u8; 160];
    assert_eq!(f.read_at(0, &mut back).unwrap(), 160);
    assert_eq!(back, data);
    assert_all_degraded(&f.take_advisories());
    b.delete(&path).unwrap();
}

// ----------------------------------------------------------------------
// File surface: two-phase collectives over a noncontiguous view
// ----------------------------------------------------------------------

/// Interleaved per-rank vector view (the two-phase sweet spot): rank r
/// owns `chunk`-int cells at stride `ranks*chunk`.
fn set_interleaved_view(f: &File<'_>, ranks: usize, rank: usize, chunk: usize) {
    let cell = Datatype::vector(1, chunk, chunk as i64, &Datatype::INT).unwrap();
    let ft = Datatype::resized(&cell, 0, (ranks * chunk * 4) as i64).unwrap();
    f.set_view((rank * chunk * 4) as i64, &Datatype::INT, &ft, "native", &Info::null())
        .unwrap();
}

/// The acceptance scenario: over 4 child backends, kill any single one
/// and a collective write + read of a noncontiguous view still
/// round-trips byte-for-byte, surfacing Degraded advisories instead of
/// an error.
fn collective_roundtrip_with_dead_server(redundancy: Redundancy, label: &str) {
    let ranks = 4usize;
    let chunk = 16usize; // ints per cell → 64 B pieces over 8 B units
    let k = 256usize; // ints per rank
    for victim in 0..4 {
        let (b, plan) = backend_with_victim(4, 8, redundancy, victim);
        let backend: Arc<dyn Backend> = Arc::new(b);
        let path = tmp(&format!("coll-{label}-{victim}"));
        let advisory_counts = threads::run(ranks, |c| {
            let f = File::open_with_backend(
                c,
                &path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend.clone(),
            )
            .unwrap();
            let r = c.rank();
            set_interleaved_view(&f, c.size(), r, chunk);
            let mine: Vec<i32> = (0..k).map(|i| (r * k + i) as i32).collect();
            f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            // Kill the victim once, after every rank finished writing.
            c.barrier();
            if r == 0 {
                plan.inject_kill(ErrorClass::Io);
            }
            c.barrier();
            let mut back = vec![0i32; k];
            f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
            assert_eq!(back, mine, "rank {r} victim {victim} ({label})");
            let advisories = f.take_advisories();
            for a in &advisories {
                assert_eq!(a.class, ErrorClass::Degraded, "rank {r}: {a}");
            }
            f.close().unwrap();
            advisories.len()
        });
        assert!(
            advisory_counts.iter().sum::<usize>() > 0,
            "victim {victim} ({label}): some aggregator must report Degraded"
        );
        File::delete(&path, &Info::null()).ok();
        let _ = backend.delete(&path);
        let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    }
}

#[test]
fn collective_view_roundtrip_with_dead_server_parity() {
    collective_roundtrip_with_dead_server(Redundancy::Parity, "parity");
}

#[test]
fn collective_view_roundtrip_with_dead_server_replica() {
    collective_roundtrip_with_dead_server(Redundancy::Replica(2), "replica");
}

#[test]
fn collective_write_with_server_already_dead_roundtrips() {
    // The write side of the acceptance criterion: the server dies
    // *before* the collective write; the data must still round-trip
    // (replicas/parity carry the dead server's intended bytes).
    for (redundancy, label) in
        [(Redundancy::Parity, "parity"), (Redundancy::Replica(2), "replica")]
    {
        let ranks = 4usize;
        let chunk = 16usize;
        let k = 128usize;
        let victim = 2usize;
        let (b, plan) = backend_with_victim(4, 8, redundancy, victim);
        plan.inject_kill(ErrorClass::Io);
        let backend: Arc<dyn Backend> = Arc::new(b);
        let path = tmp(&format!("collw-{label}"));
        let advisory_counts = threads::run(ranks, |c| {
            let f = File::open_with_backend(
                c,
                &path,
                amode::RDWR | amode::CREATE,
                Info::null(),
                backend.clone(),
            )
            .unwrap();
            let r = c.rank();
            set_interleaved_view(&f, c.size(), r, chunk);
            let mine: Vec<i32> = (0..k).map(|i| (7 * r * k + i) as i32).collect();
            f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
            c.barrier();
            let mut back = vec![0i32; k];
            f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
            assert_eq!(back, mine, "rank {r} ({label})");
            let advisories = f.take_advisories();
            for a in &advisories {
                assert_eq!(a.class, ErrorClass::Degraded, "rank {r}: {a}");
            }
            f.close().unwrap();
            advisories.len()
        });
        assert!(
            advisory_counts.iter().sum::<usize>() > 0,
            "({label}) some aggregator must report Degraded"
        );
        let _ = backend.delete(&path);
        let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    }
}

#[test]
fn server_dying_mid_collective_read_degrades() {
    // The victim answers its first vectored read, then dies — some
    // aggregators see the failure mid-collective and must reconstruct.
    let ranks = 4usize;
    let chunk = 16usize;
    let k = 512usize; // large enough that every aggregator touches every server
    let (b, plan) = backend_with_victim(4, 8, Redundancy::Parity, 1);
    let backend: Arc<dyn Backend> = Arc::new(b);
    let path = tmp("midcoll");
    let advisory_counts = threads::run(ranks, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        let r = c.rank();
        set_interleaved_view(&f, c.size(), r, chunk);
        let mine: Vec<i32> = (0..k).map(|i| (r * k + i) as i32).collect();
        f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
        c.barrier();
        if r == 0 {
            // Let exactly one more vectored read through, then fail all.
            let next = plan.count(FaultOp::ReadRuns);
            plan.inject(vec![FaultRule::from_nth(FaultOp::ReadRuns, next + 1, ErrorClass::Io)]);
        }
        c.barrier();
        let mut back = vec![0i32; k];
        f.read_at_all(0, back.as_mut_slice(), 0, k, &Datatype::INT).unwrap();
        assert_eq!(back, mine, "rank {r}");
        let advisories = f.take_advisories();
        f.close().unwrap();
        advisories.len()
    });
    assert!(
        advisory_counts.iter().sum::<usize>() > 0,
        "a mid-collective death must degrade at least one aggregator"
    );
    let _ = backend.delete(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

// ----------------------------------------------------------------------
// Sidecar fault path (satellite): failed writes must not publish
// ----------------------------------------------------------------------

#[test]
fn failed_write_does_not_publish_stale_size() {
    // One-shot fault on the vectored write path: the dispatch fails
    // after some children may already have written, and the logical
    // size must not include the failed extension.
    let (b, plan) = backend_with_victim(4, 8, Redundancy::None, 1);
    let path = tmp("stale-size");
    let f = b.open(&path, OpenOptions::rw_create()).unwrap();
    f.write_at(0, &[1u8; 10]).unwrap();
    assert_eq!(f.size().unwrap(), 10);
    plan.inject(vec![FaultRule::once(
        FaultOp::WriteRuns,
        plan.count(FaultOp::WriteRuns),
        ErrorClass::NoSpace,
    )]);
    let err = f.write_at(0, &[2u8; 200]).unwrap_err();
    assert_eq!(err.class, ErrorClass::NoSpace);
    assert_eq!(f.size().unwrap(), 10, "failed dispatch must not move the EOF");
    // The handle stays usable and the retry publishes normally.
    assert_eq!(f.write_at(0, &[2u8; 200]).unwrap(), 200);
    assert_eq!(f.size().unwrap(), 200);
    b.delete(&path).unwrap();
}
