//! Integration tests for file views with real derived datatypes: the
//! subarray/darray matrix decompositions of §7.2.9.2, Fortran order,
//! noncontiguous memory types on both sides, and external32 views.

use jpio::comm::datatype::{ArrayOrder, Datatype};
use jpio::comm::{threads, Comm};
use jpio::io::{amode, File, Info};
use jpio::testing::{forall, Config};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-views-{}-{name}", std::process::id())
}

/// 2-D darray decomposition: 4 ranks each own a quadrant of a 16x16
/// matrix; one collective write produces the row-major global matrix.
#[test]
fn darray_quadrants_compose_global_matrix() {
    let path = tmp("darray");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let ft = Datatype::darray_block(&[16, 16], &[2, 2], c.rank(), ArrayOrder::C, &Datatype::INT)
            .unwrap();
        f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        // Block-local values = global element index.
        let (py, px) = (c.rank() / 2, c.rank() % 2);
        let mine: Vec<i32> = (0..64)
            .map(|i| {
                let gr = py * 8 + i / 8;
                let gc = px * 8 + i % 8;
                (gr * 16 + gc) as i32
            })
            .collect();
        f.write_at_all(0, mine.as_slice(), 0, 64, &Datatype::INT).unwrap();
        c.barrier();
        let mut back = vec![0i32; 64];
        f.read_at_all(0, back.as_mut_slice(), 0, 64, &Datatype::INT).unwrap();
        assert_eq!(back, mine);
        f.close().unwrap();
    });
    let raw = std::fs::read(&path).unwrap();
    let ints: Vec<i32> =
        raw.chunks_exact(4).map(|b| i32::from_le_bytes(b.try_into().unwrap())).collect();
    assert_eq!(ints, (0..256).collect::<Vec<_>>());
    File::delete(&path, &Info::null()).unwrap();
}

/// Fortran-order subarray views produce the column-major layout.
#[test]
fn fortran_order_subarray_view() {
    let path = tmp("fortran");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        // 4x4 Fortran array split into two 2x4 column bands... in
        // Fortran terms: sizes (4,4), subsizes (2,4), starts (2r, 0).
        let ft = Datatype::subarray(
            &[4, 4],
            &[2, 4],
            &[2 * c.rank(), 0],
            ArrayOrder::Fortran,
            &Datatype::INT,
        )
        .unwrap();
        f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        let mine = vec![c.rank() as i32; 8];
        f.write_at_all(0, mine.as_slice(), 0, 8, &Datatype::INT).unwrap();
        c.barrier();
        f.close().unwrap();
    });
    // Column-major: element (i,j) at j*4+i; rank owns rows 2r..2r+2 → in
    // every column, entries 0,1 are rank 0 and 2,3 are rank 1.
    let raw = std::fs::read(&path).unwrap();
    let ints: Vec<i32> =
        raw.chunks_exact(4).map(|b| i32::from_le_bytes(b.try_into().unwrap())).collect();
    for col in 0..4 {
        assert_eq!(&ints[col * 4..col * 4 + 2], &[0, 0], "col {col}");
        assert_eq!(&ints[col * 4 + 2..col * 4 + 4], &[1, 1], "col {col}");
    }
    File::delete(&path, &Info::null()).unwrap();
}

/// Noncontiguous on both sides: strided memory type through a strided
/// file view (the hardest flattening case).
#[test]
fn strided_memory_through_strided_view() {
    let path = tmp("bothsides");
    threads::run(1, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        // File view: every other int (X.X.X...).
        let ft = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&ft, 0, 8).unwrap();
        f.set_view(0, &Datatype::INT, &ft, "native", &Info::null()).unwrap();
        // Memory type: 2-int blocks every 3 ints (XX.XX.…).
        let mem = Datatype::vector(3, 2, 3, &Datatype::INT).unwrap();
        let buf: Vec<i32> = (0..9).collect(); // picks 0,1,3,4,6,7
        f.write_at(0, buf.as_slice(), 0, 1, &mem).unwrap();
        // File bytes: ints 0,1,3,4,6,7 at file positions 0,2,4,6,8,10.
        let mut flat = vec![-1i32; 12];
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        f.read_at(0, flat.as_mut_slice(), 0, 11, &Datatype::INT).unwrap();
        assert_eq!(flat[0], 0);
        assert_eq!(flat[2], 1);
        assert_eq!(flat[4], 3);
        assert_eq!(flat[6], 4);
        assert_eq!(flat[8], 6);
        assert_eq!(flat[10], 7);
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// external32 through a strided view round-trips and is byte-reversed on
/// disk in exactly the view's payload positions.
#[test]
fn external32_strided_view() {
    let path = tmp("ext32");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let n = c.size();
        let slot = Datatype::vector(1, 1, 1, &Datatype::INT).unwrap();
        let ft = Datatype::resized(&slot, 0, (n * 4) as i64).unwrap();
        f.set_view((c.rank() * 4) as i64, &Datatype::INT, &ft, "external32", &Info::null())
            .unwrap();
        let mine: Vec<i32> = (0..64).map(|i| 0x0102_0300 + (i * n + c.rank()) as i32).collect();
        f.write_at_all(0, mine.as_slice(), 0, 64, &Datatype::INT).unwrap();
        c.barrier();
        let mut back = vec![0i32; 64];
        f.read_at_all(0, back.as_mut_slice(), 0, 64, &Datatype::INT).unwrap();
        assert_eq!(back, mine);
        f.close().unwrap();
    });
    // On disk everything is big-endian.
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(raw[0], 0x01, "disk bytes must be big-endian");
    File::delete(&path, &Info::null()).unwrap();
}

/// Property: for random interleaved (blocklen, nranks) decompositions, a
/// collective write through per-rank views followed by a flat read
/// reconstructs the identity sequence.
#[test]
fn prop_random_interleavings_reconstruct() {
    forall(
        Config::default().cases(12).seed(0xF11E),
        |r| (r.range(2, 4), r.range(1, 8), r.range(2, 40)),
        |&(nranks, blocklen, frames)| {
            let path = tmp(&format!("prop-{nranks}-{blocklen}-{frames}"));
            threads::run(nranks, |c| {
                let f =
                    File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
                let n = c.size();
                let cell =
                    Datatype::vector(1, blocklen, blocklen as i64, &Datatype::INT).unwrap();
                let ft =
                    Datatype::resized(&cell, 0, (n * blocklen * 4) as i64).unwrap();
                f.set_view(
                    (c.rank() * blocklen * 4) as i64,
                    &Datatype::INT,
                    &ft,
                    "native",
                    &Info::null(),
                )
                .unwrap();
                let k = frames * blocklen;
                let mine: Vec<i32> = (0..k)
                    .map(|i| {
                        let frame = i / blocklen;
                        let inner = i % blocklen;
                        (frame * n * blocklen + c.rank() * blocklen + inner) as i32
                    })
                    .collect();
                f.write_at_all(0, mine.as_slice(), 0, k, &Datatype::INT).unwrap();
                c.barrier();
                f.close().unwrap();
            });
            let raw = std::fs::read(&path).unwrap();
            let ints: Vec<i32> = raw
                .chunks_exact(4)
                .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            let ok = ints == (0..(nranks * blocklen * frames) as i32).collect::<Vec<_>>();
            File::delete(&path, &Info::null()).unwrap();
            ok
        },
    );
}
