//! Multi-lane progress (`jpio_progress_threads > 1`): independent
//! nonblocking collectives pipeline across per-world progress lanes,
//! while the per-file op sequencer keeps their *storage phases* in issue
//! order — the MPI ordering contract for overlapping collectives. Plus
//! the zero-copy regression guard: collective writes on plan-executing
//! backends (striped) must stage zero payload bytes, observable through
//! the `staging_copy_bytes` counter.

use std::sync::Arc;

use jpio::comm::{process, threads, Comm, Datatype, ReduceOp};
use jpio::io::hints::keys;
use jpio::io::{amode, File, Info};
use jpio::storage::striped::StripedBackend;
use jpio::storage::Backend;

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-multilane-{}-{name}", std::process::id())
}

fn two_lanes() -> Info {
    Info::from([(keys::PROGRESS_THREADS, "2")])
}

#[test]
fn two_lanes_pipeline_disjoint_collectives_across_processes() {
    // Forked ranks: two independent nonblocking collective writes in
    // flight at once (one per lane), then two reads — everything must
    // land, across real address spaces.
    let path = tmp("procs");
    process::run_local(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, two_lanes()).unwrap();
        let r = c.rank();
        let a = vec![(1 + r) as u8; 256];
        let b = vec![(11 + r) as u8; 256];
        let w1 = f.iwrite_at_all((r * 256) as i64, a.as_slice(), 0, 256, &Datatype::BYTE)
            .unwrap();
        let w2 = f
            .iwrite_at_all((512 + r * 256) as i64, b.as_slice(), 0, 256, &Datatype::BYTE)
            .unwrap();
        let (st1, ()) = w1.wait().unwrap();
        let (st2, ()) = w2.wait().unwrap();
        assert_eq!((st1.bytes, st2.bytes), (256, 256));
        c.barrier();
        let r1 = f.iread_at_all(0, vec![0u8; 512], 0, 512, &Datatype::BYTE).unwrap();
        let r2 = f.iread_at_all(512, vec![0u8; 512], 0, 512, &Datatype::BYTE).unwrap();
        let (_, lo) = r1.wait().unwrap();
        let (_, hi) = r2.wait().unwrap();
        assert!(lo[..256].iter().all(|&v| v == 1));
        assert!(lo[256..].iter().all(|&v| v == 2));
        assert!(hi[..256].iter().all(|&v| v == 11));
        assert!(hi[256..].iter().all(|&v| v == 12));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

#[test]
fn overlapping_collectives_complete_in_issue_order_on_two_lanes() {
    // Two nonblocking collective writes to the SAME region, issued
    // back-to-back: with two lanes their exchanges pipeline, but the op
    // sequencer must serialize the storage phases in issue order — the
    // second write's bytes win, every iteration.
    let path = tmp("order");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, two_lanes()).unwrap();
        let r = c.rank();
        for k in 0..8u64 {
            let base = (k * 1024) as i64;
            let first = vec![0x11u8; 256];
            let second = vec![0x22u8; 256];
            let w1 = f
                .iwrite_at_all(base + (r * 256) as i64, first.as_slice(), 0, 256, &Datatype::BYTE)
                .unwrap();
            let w2 = f
                .iwrite_at_all(base + (r * 256) as i64, second.as_slice(), 0, 256, &Datatype::BYTE)
                .unwrap();
            // Wait in reverse order: completion order must not matter,
            // only issue order.
            let (st2, ()) = w2.wait().unwrap();
            let (st1, ()) = w1.wait().unwrap();
            assert_eq!((st1.bytes, st2.bytes), (256, 256));
            c.barrier();
            let rd = f.iread_at_all(base, vec![0u8; 1024], 0, 1024, &Datatype::BYTE).unwrap();
            let (_, back) = rd.wait().unwrap();
            assert!(
                back.iter().all(|&v| v == 0x22),
                "iteration {k}: an earlier collective overwrote a later one"
            );
            c.barrier();
        }
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn read_after_write_sees_the_write_across_lanes() {
    // A nonblocking collective read issued right behind a nonblocking
    // collective write of the same region: the read lands on the other
    // lane, and the sequencer must hold its whole collective behind the
    // write's storage phase.
    let path = tmp("raw");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, two_lanes()).unwrap();
        let r = c.rank();
        let mine: Vec<u8> = (0..128).map(|i| (r * 128 + i) as u8).collect();
        let w = f.iwrite_at_all((r * 128) as i64, mine.as_slice(), 0, 128, &Datatype::BYTE)
            .unwrap();
        let rd = f.iread_at_all(0, vec![0u8; 512], 0, 512, &Datatype::BYTE).unwrap();
        let (_, ()) = w.wait().unwrap();
        let (st, all) = rd.wait().unwrap();
        assert_eq!(st.bytes, 512);
        assert_eq!(all, (0..=255u8).chain(0..=255u8).collect::<Vec<_>>());
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
}

#[test]
fn collective_writes_on_striped_storage_stage_zero_bytes() {
    // The zero-copy regression guard. On a plan-executing backend the
    // aggregator hands exchange pieces straight to the per-server
    // dispatch: no rank may count a single staged payload byte. On a
    // single-device backend the staged path remains, and the world-wide
    // staging traffic equals the payload — never more.
    let striped_path = tmp("zc-striped");
    let backend: Arc<dyn Backend> = Arc::new(StripedBackend::local(4, 64));
    threads::run(4, |c| {
        let f = File::open_with_backend(
            c,
            &striped_path,
            amode::RDWR | amode::CREATE,
            Info::null(),
            backend.clone(),
        )
        .unwrap();
        let r = c.rank();
        let mine = vec![(1 + r) as u8; 512];
        f.write_at_all((r * 512) as i64, mine.as_slice(), 0, 512, &Datatype::BYTE).unwrap();
        let req = f
            .iwrite_at_all((2048 + r * 512) as i64, mine.as_slice(), 0, 512, &Datatype::BYTE)
            .unwrap();
        req.wait().unwrap();
        c.barrier();
        let staged = f.stats().counter("staging_copy_bytes").sum;
        assert_eq!(staged, 0, "rank {r} staged {staged} bytes on the zero-copy path");
        let mut back = vec![0u8; 4096];
        f.read_at_all(0, back.as_mut_slice(), 0, 4096, &Datatype::BYTE).unwrap();
        for rr in 0..4usize {
            assert!(back[rr * 512..(rr + 1) * 512].iter().all(|&v| v == (1 + rr) as u8));
            assert!(back[2048 + rr * 512..2048 + (rr + 1) * 512]
                .iter()
                .all(|&v| v == (1 + rr) as u8));
        }
        f.close().unwrap();
    });
    let _ = backend.delete(&striped_path);

    let local_path = tmp("zc-local");
    threads::run(4, |c| {
        let f = File::open(c, &local_path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let r = c.rank();
        let mine = vec![(1 + r) as u8; 512];
        f.write_at_all((r * 512) as i64, mine.as_slice(), 0, 512, &Datatype::BYTE).unwrap();
        c.barrier();
        let staged = c.allreduce_i64(
            ReduceOp::Sum,
            f.stats().counter("staging_copy_bytes").sum as i64,
        );
        assert_eq!(
            staged, 2048,
            "staged path must copy each payload byte exactly once world-wide"
        );
        f.close().unwrap();
    });
    let _ = std::fs::remove_file(&local_path);
    let _ = std::fs::remove_file(format!("{local_path}.jpio-sfp"));
}
