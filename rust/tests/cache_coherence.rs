//! Page-cache coherence: the `jpio_cache` write-behind layer must honour
//! the MPI consistency contract (§7.2.6.1 writer-sync → barrier →
//! reader-sync), keep atomic mode strictly serialized, stay
//! byte-identical to the uncached path, and preserve degraded-mode
//! advisories raised by its read-modify-write pre-reads — across forked
//! processes, not just threads.

use std::sync::Arc;

use jpio::comm::{process, threads, Comm, Datatype};
use jpio::io::{amode, ErrorClass, File, Info};
use jpio::storage::faults::{FaultBackend, FaultPlan};
use jpio::storage::layout::Redundancy;
use jpio::storage::local::LocalBackend;
use jpio::storage::striped::StripedBackend;
use jpio::storage::Backend;

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-cachetest-{}-{name}", std::process::id())
}

fn cache_info() -> Info {
    Info::from([("jpio_cache", "enable")])
}

fn cleanup(path: &str) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(format!("{path}.jpio-sfp"));
    let _ = std::fs::remove_file(format!("{path}.jpio-cache-lease"));
}

/// Writer-sync → barrier → reader-sync across forked processes: the
/// reader's sync must observe the writer's lease bump, invalidate its
/// resident pages, and see the write-behind data.
#[test]
fn sync_makes_cached_writes_visible_across_processes() {
    let path = tmp("sync");
    process::run_local(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, cache_info()).unwrap();
        let n = 4096usize;
        if c.rank() == 0 {
            // Small strided writes absorbed by the cache, published at
            // sync as coalesced flushes.
            for off in (0..n).step_by(64) {
                let piece: Vec<u8> = (off..off + 16).map(|v| v as u8).collect();
                f.write_at(off as i64, piece.as_slice(), 0, 16, &Datatype::BYTE).unwrap();
            }
            f.sync().unwrap();
        } else {
            // Prime the reader's cache with the pre-write state so the
            // later read cannot pass by accident of an empty cache.
            let mut probe = vec![0u8; 16];
            let _ = f.read_at(0, probe.as_mut_slice(), 0, 16, &Datatype::BYTE).unwrap();
        }
        c.barrier();
        if c.rank() == 1 {
            f.sync().unwrap();
            for off in (0..n).step_by(64) {
                let mut back = vec![0u8; 16];
                assert_eq!(
                    f.read_at(off as i64, back.as_mut_slice(), 0, 16, &Datatype::BYTE)
                        .unwrap()
                        .bytes,
                    16
                );
                let want: Vec<u8> = (off..off + 16).map(|v| v as u8).collect();
                assert_eq!(back, want, "stale read at {off} after writer-sync/reader-sync");
            }
        }
        c.barrier();
        f.close().unwrap();
    });
    cleanup(&path);
}

/// Close is a coherence point: a second handle opened after the first
/// closed must see every write-behind byte.
#[test]
fn close_publishes_write_behind_data_to_a_later_handle() {
    let path = tmp("close");
    threads::run(1, |c| {
        let writer = File::open(c, &path, amode::RDWR | amode::CREATE, cache_info()).unwrap();
        let data: Vec<u8> = (0..200u8).collect();
        writer.write_at(40, data.as_slice(), 0, 200, &Datatype::BYTE).unwrap();
        // Nothing forced the flush yet; close must.
        writer.close().unwrap();
        let reader = File::open(c, &path, amode::RDONLY, cache_info()).unwrap();
        assert_eq!(reader.get_size().unwrap(), 240);
        let mut back = vec![0u8; 200];
        assert_eq!(
            reader.read_at(40, back.as_mut_slice(), 0, 200, &Datatype::BYTE).unwrap().bytes,
            200
        );
        assert_eq!(back, data);
        reader.close().unwrap();
    });
    cleanup(&path);
}

/// Two live handles on one path in one process: the writer's sync and
/// the reader's sync bracket visibility through the lease sidecar.
#[test]
fn writer_then_reader_handles_on_one_path() {
    let path = tmp("two-handles");
    threads::run(1, |c| {
        let writer = File::open(c, &path, amode::RDWR | amode::CREATE, cache_info()).unwrap();
        let reader = File::open(c, &path, amode::RDWR | amode::CREATE, cache_info()).unwrap();
        writer.write_at(0, [1u8; 64].as_slice(), 0, 64, &Datatype::BYTE).unwrap();
        writer.sync().unwrap();
        reader.sync().unwrap();
        let mut back = vec![0u8; 64];
        reader.read_at(0, back.as_mut_slice(), 0, 64, &Datatype::BYTE).unwrap();
        assert_eq!(back, [1u8; 64], "first generation not visible");
        // Overwrite through the writer's cache; the reader still holds
        // generation-1 pages until its own sync.
        writer.write_at(0, [2u8; 64].as_slice(), 0, 64, &Datatype::BYTE).unwrap();
        writer.sync().unwrap();
        reader.sync().unwrap();
        reader.read_at(0, back.as_mut_slice(), 0, 64, &Datatype::BYTE).unwrap();
        assert_eq!(back, [2u8; 64], "reader-sync must invalidate resident pages");
        writer.close().unwrap();
        reader.close().unwrap();
    });
    cleanup(&path);
}

/// Atomic mode with the cache enabled: operations serialize under the
/// whole-file lock and bypass resident pages entirely, so a write is
/// visible to the other process immediately — no sync required.
#[test]
fn atomic_mode_is_coherent_without_sync_across_processes() {
    let path = tmp("atomic");
    process::run_local(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, cache_info()).unwrap();
        f.set_atomicity(true).unwrap();
        assert!(f.get_atomicity());
        if c.rank() == 0 {
            f.write_at(0, [9u8; 128].as_slice(), 0, 128, &Datatype::BYTE).unwrap();
        }
        c.barrier();
        if c.rank() == 1 {
            let mut back = vec![0u8; 128];
            assert_eq!(
                f.read_at(0, back.as_mut_slice(), 0, 128, &Datatype::BYTE).unwrap().bytes,
                128
            );
            assert_eq!(back, [9u8; 128], "atomic write invisible to peer");
        }
        c.barrier();
        f.close().unwrap();
    });
    cleanup(&path);
}

/// The same strided workload with the cache on and off must produce
/// byte-identical files, and the cache-off run must count nothing.
#[test]
fn cache_off_path_is_byte_identical_with_zero_counters() {
    let on = tmp("bytes-on");
    let off = tmp("bytes-off");
    threads::run(1, |c| {
        for (path, info) in [(&on, cache_info()), (&off, Info::null())] {
            let f = File::open(c, path, amode::RDWR | amode::CREATE, info.clone()).unwrap();
            for i in 0..64usize {
                let piece = [i as u8; 24];
                f.write_at((i * 48) as i64, piece.as_slice(), 0, 24, &Datatype::BYTE).unwrap();
            }
            // Read-modify-write traffic: overwrite the middle of the
            // strided region, then read a span back through the handle.
            f.write_at(500, [0xABu8; 100].as_slice(), 0, 100, &Datatype::BYTE).unwrap();
            let mut span = vec![0u8; 300];
            f.read_at(400, span.as_mut_slice(), 0, 300, &Datatype::BYTE).unwrap();
            let report = f.stats();
            let cached: u64 = ["cache_hit_bytes", "cache_miss_bytes", "write_behind_flush_bytes"]
                .iter()
                .map(|k| report.counter(k).sum)
                .sum();
            if info.get("jpio_cache").is_some() {
                assert!(cached > 0, "cache-on run must count cache traffic");
            } else {
                assert_eq!(cached, 0, "cache-off run must not touch the cache");
            }
            f.close().unwrap();
        }
    });
    let a = std::fs::read(&on).unwrap();
    let b = std::fs::read(&off).unwrap();
    assert_eq!(a, b, "jpio_cache=enable changed the bytes on disk");
    cleanup(&on);
    cleanup(&off);
}

/// The cache's read-modify-write pre-read runs on the shared storage
/// handle, so a degraded (parity-reconstructed) pre-read must leave its
/// `JPIO_ERR_DEGRADED` advisories drainable through `take_advisories` —
/// the flush must not eat them.
#[test]
fn rmw_pre_read_preserves_degraded_advisories() {
    let plan_faults = FaultPlan::new(vec![]);
    let children: Vec<Arc<dyn Backend>> = (0..4)
        .map(|i| {
            if i == 2 {
                Arc::new(FaultBackend::new(LocalBackend::instant(), plan_faults.clone()))
                    as Arc<dyn Backend>
            } else {
                Arc::new(LocalBackend::instant()) as Arc<dyn Backend>
            }
        })
        .collect();
    let backend: Arc<dyn Backend> =
        Arc::new(StripedBackend::with_redundancy(children, 8, Redundancy::Parity).unwrap());
    let path = tmp("degraded");
    threads::run(1, |c| {
        let f = File::open_with_backend(
            c,
            &path,
            amode::RDWR | amode::CREATE,
            cache_info(),
            backend.clone(),
        )
        .unwrap();
        // Healthy baseline on storage.
        let base: Vec<u8> = (0..96u8).collect();
        f.write_at(0, base.as_slice(), 0, 96, &Datatype::BYTE).unwrap();
        f.sync().unwrap();
        assert!(f.take_advisories().is_empty(), "healthy write must not degrade");
        // Kill a stripe server, then dirty two disjoint extents of one
        // page: the flush coalesces them into a covering run, whose
        // gap-filling pre-read reconstructs around the dead server.
        plan_faults.inject_kill(ErrorClass::Io);
        f.write_at(3, [0x11u8; 20].as_slice(), 0, 20, &Datatype::BYTE).unwrap();
        f.write_at(70, [0x22u8; 12].as_slice(), 0, 12, &Datatype::BYTE).unwrap();
        f.sync().unwrap();
        let advisories = f.take_advisories();
        assert!(!advisories.is_empty(), "degraded RMW pre-read must be advised");
        assert!(advisories.iter().all(|a| a.class == ErrorClass::Degraded));
        // The merged bytes are correct despite the reconstruction.
        let mut back = vec![0u8; 96];
        assert_eq!(
            f.read_at(0, back.as_mut_slice(), 0, 96, &Datatype::BYTE).unwrap().bytes,
            96
        );
        let mut want = base.clone();
        want[3..23].copy_from_slice(&[0x11u8; 20]);
        want[70..82].copy_from_slice(&[0x22u8; 12]);
        assert_eq!(back, want);
        // The RMW cycle was counted.
        assert!(f.stats().counter("rmw_cycles").sum >= 1, "rmw_cycles not counted");
        f.close().unwrap();
    });
    for s in 0..4 {
        let _ = std::fs::remove_file(StripedBackend::object_path(&path, s, 4));
    }
    cleanup(&path);
}
