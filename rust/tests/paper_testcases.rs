//! The paper's §3.6 prototype test cases, reproduced one-for-one:
//! `Coll_test.java`, `Async_test.java`, `Atomicity_test.java`,
//! `Misc_test.java`, `Perf.java`.

use jpio::comm::{threads, Comm, Datatype};
use jpio::io::{amode, seek, File, Info};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-papertest-{}-{name}", std::process::id())
}

/// §3.6.1 Coll_test: "uses collective read and write operation to write
/// and then read file. 1KB data is first written and then read."
#[test]
fn paper_coll_test() {
    let path = tmp("coll");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let buf: Vec<u8> = (0..1024u32).map(|i| (i + c.rank() as u32) as u8).collect();
        let st = f
            .write_at_all((c.rank() * 1024) as i64, buf.as_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        assert_eq!(st.bytes, 1024);
        c.barrier();
        let mut back = vec![0u8; 1024];
        let st = f
            .read_at_all((c.rank() * 1024) as i64, back.as_mut_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        assert_eq!(st.bytes, 1024);
        assert_eq!(back, buf);
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// §3.6.2 Async_test: "uses non-blocking read and write operation to
/// write and then read file. 1KB data."
#[test]
fn paper_async_test() {
    let path = tmp("async");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let buf: Vec<u8> = vec![c.rank() as u8; 1024];
        let req = f
            .iwrite_at((c.rank() * 1024) as i64, buf.as_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        let (st, ()) = req.wait().unwrap();
        assert_eq!(st.bytes, 1024);
        c.barrier();
        let req = f
            .iread_at((c.rank() * 1024) as i64, vec![0u8; 1024], 0, 1024, &Datatype::BYTE)
            .unwrap();
        let (st, back) = req.wait().unwrap();
        assert_eq!(st.bytes, 1024);
        assert_eq!(back, buf);
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// §3.6.3 Atomicity_test: "simple blocking read and write operation with
/// an addition of set_atomicity() and get_atomicity() methods."
#[test]
fn paper_atomicity_test() {
    let path = tmp("atomicity");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_atomicity(true).unwrap();
        assert!(f.get_atomicity());
        let buf = vec![c.rank() as u8; 1024];
        f.write_at((c.rank() * 1024) as i64, buf.as_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        c.barrier();
        let mut back = vec![0u8; 1024];
        f.read_at((c.rank() * 1024) as i64, back.as_mut_slice(), 0, 1024, &Datatype::BYTE)
            .unwrap();
        assert_eq!(back, buf);
        f.set_atomicity(false).unwrap();
        assert!(!f.get_atomicity());
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// §3.6.4 Misc_test: "blocking read and write operations along with ...
/// getPosition(), getByteOffset() and seek()."
#[test]
fn paper_misc_test() {
    let path = tmp("misc");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        let buf: Vec<i32> = (0..256).collect(); // 1 KB of ints
        f.seek((c.rank() * 256) as i64, seek::SET).unwrap();
        f.write(buf.as_slice(), 0, 256, &Datatype::INT).unwrap();
        assert_eq!(f.get_position().unwrap(), (c.rank() * 256 + 256) as i64);
        assert_eq!(
            f.get_byte_offset((c.rank() * 256) as i64).unwrap(),
            (c.rank() * 1024) as i64
        );
        f.seek(-256, seek::CUR).unwrap();
        let mut back = vec![0i32; 256];
        f.read(back.as_mut_slice(), 0, 256, &Datatype::INT).unwrap();
        assert_eq!(back, buf);
        c.barrier();
        f.seek(0, seek::END).unwrap();
        assert_eq!(f.get_position().unwrap(), 512);
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// §3.6.5 Perf: "simple read and write operations are performed without
/// sync() ... after this ... with the sync() method call" — functional
/// version (the measured version is `cargo bench --bench fig4_6_prototype`).
#[test]
fn paper_perf_test_functional() {
    let path = tmp("perf");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        let buf = vec![7u8; 1024];
        f.seek((c.rank() * 64 * 1024) as i64, seek::SET).unwrap();
        for _ in 0..32 {
            f.write(buf.as_slice(), 0, 1024, &Datatype::BYTE).unwrap();
        }
        for _ in 0..32 {
            f.write(buf.as_slice(), 0, 1024, &Datatype::BYTE).unwrap();
            f.sync().unwrap();
        }
        f.seek((c.rank() * 64 * 1024) as i64, seek::SET).unwrap();
        let mut back = vec![0u8; 1024];
        for _ in 0..64 {
            let st = f.read(back.as_mut_slice(), 0, 1024, &Datatype::BYTE).unwrap();
            assert_eq!(st.bytes, 1024);
            assert_eq!(back, buf);
        }
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}
