//! The consistency-semantics examples of §7.2.6.10, executed as tests.
//!
//! * Example 1 — sequential consistency via **atomic mode**: process 0
//!   writes, process 1 reads the same region; with atomicity enabled the
//!   read sees either none or all of the write, never a torn mix.
//! * Example 2 — the **sync / barrier / sync** recipe in nonatomic mode.
//! * Example 3 — the *erroneous* shortcut (one sync only) the spec warns
//!   about: we verify the legal recipe works rather than relying on the
//!   illegal one failing (it may "work" by luck on a local FS — that is
//!   exactly the paper's point about implementation-defined outcomes).

use jpio::comm::{threads, Comm, Datatype};
use jpio::io::{amode, File, Info};

fn tmp(name: &str) -> String {
    format!("/tmp/jpio-consistency-{}-{name}", std::process::id())
}

/// §7.2.6.10 Example 1: atomic mode makes concurrent conflicting access
/// well-defined.
#[test]
fn example1_sequential_consistency_by_atomic_mode() {
    let path = tmp("ex1");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        f.set_atomicity(true).unwrap();
        // Pre-fill with a known pattern so "none of the write" is
        // distinguishable.
        if c.rank() == 0 {
            f.write_at(0, vec![-1i32; 10].as_slice(), 0, 10, &Datatype::INT).unwrap();
            f.sync().unwrap();
        }
        c.barrier();
        for round in 0..50 {
            if c.rank() == 0 {
                let a = vec![round as i32; 10];
                f.write_at(0, a.as_slice(), 0, 10, &Datatype::INT).unwrap();
            } else {
                let mut b = vec![0i32; 10];
                let st = f.read_at(0, b.as_mut_slice(), 0, 10, &Datatype::INT).unwrap();
                assert_eq!(st.bytes, 40);
                // Atomicity: all ten ints must be identical (some round's
                // complete write, or the prefill) — never torn.
                assert!(
                    b.windows(2).all(|w| w[0] == w[1]),
                    "torn read in atomic mode: {b:?}"
                );
            }
        }
        c.barrier();
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// §7.2.6.10 Example 2: nonatomic mode + sync/barrier/sync.
#[test]
fn example2_sync_barrier_sync() {
    let path = tmp("ex2");
    threads::run(2, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_view(0, &Datatype::INT, &Datatype::INT, "native", &Info::null()).unwrap();
        if c.rank() == 0 {
            let a = vec![5i32; 10];
            f.write_at(0, a.as_slice(), 0, 10, &Datatype::INT).unwrap();
            f.sync().unwrap(); // flush my writes
            c.barrier();
            f.sync().unwrap(); // see others' (none here)
        } else {
            f.sync().unwrap();
            c.barrier();
            f.sync().unwrap(); // makes rank 0's flushed data visible
            let mut b = vec![0i32; 10];
            let st = f.read_at(0, b.as_mut_slice(), 0, 10, &Datatype::INT).unwrap();
            assert_eq!(st.bytes, 40);
            assert_eq!(b, vec![5i32; 10]);
        }
        c.barrier();
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// §7.2.6.10 Example 3 (the erroneous variant, made legal): the full
/// recipe must also work through two *separate* collective opens.
#[test]
fn example3_two_separate_opens() {
    let path = tmp("ex3");
    threads::run(2, |c| {
        // Writer epoch.
        let f1 = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        if c.rank() == 0 {
            f1.write_at(0, vec![9i32; 10].as_slice(), 0, 10 * 4, &Datatype::BYTE)
                .map(|_| ())
                .unwrap_err(); // datatype mismatch guard (BYTE vs i32 buf)
            f1.write_at(0, vec![9u8; 40].as_slice(), 0, 40, &Datatype::BYTE).unwrap();
            f1.sync().unwrap();
        }
        f1.close().unwrap(); // close is a sync point
        c.barrier();
        // Reader epoch: a second collective open must observe the data.
        let f2 = File::open(c, &path, amode::RDONLY, Info::null()).unwrap();
        let mut b = vec![0u8; 40];
        let st = f2.read_at(0, b.as_mut_slice(), 0, 40, &Datatype::BYTE).unwrap();
        assert_eq!(st.bytes, 40);
        assert!(b.iter().all(|&v| v == 9));
        f2.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// Concurrent non-overlapping writes need no atomicity (§3.5.3:
/// "MPI-IO guarantees the concurrent nonoverlapping writes correctly").
#[test]
fn nonoverlapping_writes_are_always_safe() {
    let path = tmp("nonoverlap");
    threads::run(8, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        assert!(!f.get_atomicity());
        let mine = vec![c.rank() as u8; 4096];
        f.write_at((c.rank() * 4096) as i64, mine.as_slice(), 0, 4096, &Datatype::BYTE)
            .unwrap();
        c.barrier();
        let mut all = vec![0u8; 8 * 4096];
        f.read_at(0, all.as_mut_slice(), 0, 8 * 4096, &Datatype::BYTE).unwrap();
        for (i, chunk) in all.chunks_exact(4096).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u8), "region {i} corrupted");
        }
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}

/// Atomic-mode overlapping writes from many ranks leave one complete
/// winner per region (stress version of Example 1).
#[test]
fn atomic_overlapping_writes_are_untorn() {
    let path = tmp("atomicstress");
    threads::run(4, |c| {
        let f = File::open(c, &path, amode::RDWR | amode::CREATE, Info::null()).unwrap();
        f.set_atomicity(true).unwrap();
        let mine = vec![c.rank() as i32 + 1; 1024];
        for _ in 0..8 {
            f.write_at(0, mine.as_slice(), 0, 1024, &Datatype::INT).unwrap();
        }
        c.barrier();
        let mut back = vec![0i32; 1024];
        f.read_at(0, back.as_mut_slice(), 0, 1024, &Datatype::INT).unwrap();
        assert!(back.windows(2).all(|w| w[0] == w[1]), "torn atomic write");
        assert!((1..=4).contains(&back[0]));
        f.close().unwrap();
    });
    File::delete(&path, &Info::null()).unwrap();
}
