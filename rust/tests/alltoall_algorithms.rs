//! Transport-level properties of the scalable alltoall schedules.
//!
//! Two contracts back the scale-out exchange path:
//!
//! * **equivalence** — `pairwise` and `bruck` must deliver byte-identical
//!   inbound sets to the `linear` baseline on every world size and any
//!   skew of per-destination payload sizes (including empty parts), since
//!   the collective layer switches between them purely on hints and
//!   `Auto` thresholds;
//! * **no self-traffic** — the rank-to-self payload is moved, never
//!   serialized: a counting transport tap must observe zero bytes sent to
//!   the own rank under every algorithm.

use std::sync::atomic::{AtomicU64, Ordering};

use jpio::comm::{threads, AlltoallAlgorithm, Comm};

/// Deterministic skewed payload from `src` to `dst`: sizes vary with the
/// pair (some pairs exchange nothing), bytes encode the pair and index so
/// misrouted or reordered blocks cannot collide.
fn part(src: usize, dst: usize) -> Vec<u8> {
    if (src + dst) % 5 == 0 {
        return Vec::new();
    }
    let len = (src * 7 + dst * 13) % 97 + 1;
    (0..len).map(|i| (src * 31 + dst * 17 + i) as u8).collect()
}

const ALGOS: [AlltoallAlgorithm; 4] = [
    AlltoallAlgorithm::Linear,
    AlltoallAlgorithm::Pairwise,
    AlltoallAlgorithm::Bruck,
    AlltoallAlgorithm::Auto,
];

#[test]
fn algorithms_deliver_identical_bytes_across_world_sizes() {
    // Odd, even, power-of-two, and past the Auto threshold — the shapes
    // that pick different pairwise partnering and Bruck round counts.
    for n in [1usize, 2, 3, 4, 5, 7, 8, 9, 16] {
        for algo in ALGOS {
            threads::run(n, |c| {
                let me = c.rank();
                let parts: Vec<Vec<u8>> = (0..n).map(|d| part(me, d)).collect();
                let inbound = c.alltoall_with(&parts, algo);
                let want: Vec<Vec<u8>> = (0..n).map(|s| part(s, me)).collect();
                assert_eq!(
                    inbound, want,
                    "rank {me}/{n} inbound mismatch under {algo:?}"
                );
            });
        }
    }
}

/// A transport tap: forwards the point-to-point primitives to the inner
/// endpoint, counting payload bytes pushed toward each destination. The
/// alltoall default implementations run on top of these, so any
/// algorithm that serialized rank-to-self traffic would be caught here.
struct CountingComm<'a, C: Comm> {
    inner: &'a C,
    self_bytes: AtomicU64,
    wire_bytes: AtomicU64,
}

impl<'a, C: Comm> CountingComm<'a, C> {
    fn new(inner: &'a C) -> Self {
        CountingComm { inner, self_bytes: AtomicU64::new(0), wire_bytes: AtomicU64::new(0) }
    }
}

impl<C: Comm> Comm for CountingComm<'_, C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&self, dest: usize, tag: i32, data: &[u8]) {
        if dest == self.inner.rank() {
            self.self_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        }
        self.wire_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.inner.send(dest, tag, data);
    }

    fn recv(&self, src: usize, tag: i32) -> Vec<u8> {
        self.inner.recv(src, tag)
    }

    fn try_recv(&self, src: usize, tag: i32) -> Option<Vec<u8>> {
        self.inner.try_recv(src, tag)
    }

    fn barrier(&self) {
        self.inner.barrier()
    }
}

#[test]
fn counting_tap_observes_deliberate_self_traffic() {
    // Sanity of the tap itself: a hand-rolled send-to-self must be
    // counted, or the zero assertions below would be vacuous.
    threads::run(2, |c| {
        let tap = CountingComm::new(c);
        tap.send(tap.rank(), 77, b"loop");
        assert_eq!(tap.recv(tap.rank(), 77), b"loop");
        assert_eq!(tap.self_bytes.load(Ordering::Relaxed), 4);
    });
}

#[test]
fn no_alltoall_algorithm_sends_self_bytes_to_transport() {
    for n in [2usize, 5, 8, 16] {
        for algo in ALGOS {
            threads::run(n, |c| {
                let tap = CountingComm::new(c);
                let me = tap.rank();
                // Non-empty self part on every rank: the bytes that must
                // move hands without touching the transport.
                let parts: Vec<Vec<u8>> =
                    (0..n).map(|d| vec![(me * n + d) as u8; 64]).collect();
                let inbound = tap.alltoall_owned(parts, algo);
                for (s, got) in inbound.iter().enumerate() {
                    assert_eq!(got, &vec![(s * n + me) as u8; 64], "rank {me} from {s}");
                }
                assert_eq!(
                    tap.self_bytes.load(Ordering::Relaxed),
                    0,
                    "rank {me}/{n}: {algo:?} serialized rank-to-self traffic"
                );
                assert!(
                    tap.wire_bytes.load(Ordering::Relaxed) > 0,
                    "rank {me}/{n}: {algo:?} sent nothing — tap not on the path?"
                );
            });
        }
    }
}

#[test]
fn sendrecv_self_shortcut_returns_payload_untouched() {
    threads::run(3, |c| {
        let tap = CountingComm::new(c);
        let me = tap.rank();
        let data = vec![me as u8; 33];
        let back = tap.sendrecv(me, 9, &data, me, 9);
        assert_eq!(back, data);
        assert_eq!(tap.self_bytes.load(Ordering::Relaxed), 0);
        assert_eq!(tap.wire_bytes.load(Ordering::Relaxed), 0);
    });
}
